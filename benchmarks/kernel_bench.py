"""Kernel-layer benchmark: the CEFT level-relaxation contraction.

On this CPU container the Pallas kernels are validated in interpret mode
(correctness only -- interpret timing is meaningless); the measurable proxy is
the XLA fused relaxation at the same shapes, reported as relaxations/s and
effective GB/s.  On TPU the same harness times the Pallas kernel itself.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ceft_jax import xla_relax
from repro.kernels import ceft_relax
from repro.kernels.ref import ceft_relax_ref

from .common import CSV, scale

SHAPES = [(256, 4, 16), (256, 8, 64), (1024, 4, 64), (1024, 8, 128)]


def run(seed: int = 3):
    csv = CSV(["bench", "W", "D", "P", "impl", "us_per_call", "GB_per_s",
               "max_abs_err_vs_ref"])
    rng = np.random.default_rng(seed)
    relax_jit = jax.jit(xla_relax)
    for (W, D, P) in SHAPES:
        pv = jnp.asarray(rng.uniform(0, 100, (W, D, P)), jnp.float32)
        pdata = jnp.asarray(rng.uniform(0, 10, (W, D)), jnp.float32)
        validb = jnp.asarray(rng.random((W, D)) < 0.9)
        L = jnp.asarray(rng.uniform(0, 2, (P,)), jnp.float32)
        bw = jnp.asarray(rng.uniform(0.5, 2, (P, P)), jnp.float32)

        out = relax_jit(pv, pdata, validb, L, bw)
        out[0].block_until_ready()
        reps = 20
        t0 = time.perf_counter()
        for _ in range(reps):
            out = relax_jit(pv, pdata, validb, L, bw)
        out[0].block_until_ready()
        t = (time.perf_counter() - t0) / reps
        # bytes through the fused op: inputs + outputs (the kernel's HBM model)
        bts = 4 * (W * D * P + 2 * W * D + P + P * P + 3 * W * P)
        want = ceft_relax_ref(pv, pdata, validb.astype(jnp.float32), L, bw)
        err = float(jnp.max(jnp.abs(out[0] - want[0])))
        csv.row("relax_xla", W, D, P, "xla_fused", f"{t * 1e6:.1f}",
                f"{bts / t / 1e9:.2f}", f"{err:.1e}")

        # Pallas interpret-mode: correctness cross-check at bench shapes
        got = ceft_relax(pv, pdata, validb.astype(jnp.float32), L, bw)
        errp = float(jnp.max(jnp.abs(got[0] - want[0])))
        csv.row("relax_pallas_interpret", W, D, P, "pallas", "-", "-",
                f"{errp:.1e}")


if __name__ == "__main__":
    run()
