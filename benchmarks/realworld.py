"""Paper Figs 15-18: SLR and speedup vs CCR on the four real-world DAGs
(FFT, GE, MD, EW), classic and medium weight variants."""
from __future__ import annotations

import numpy as np

from repro.graphs import epigenomics, fft_graph, gaussian_elimination, molecular_dynamics
from repro.graphs.rgg import classic_workload, interval_workload

from .common import CSV, cat3, run_algos, scale

GRAPHS = {
    "FFT": lambda: fft_graph(32),
    "GE": lambda: gaussian_elimination(12),
    "MD": molecular_dynamics,
    "EW": lambda: epigenomics(12),
}
CCRS = [0.001, 0.01, 0.1, 0.5, 1, 5, 10]
BETAS = [10, 25, 50, 75, 95]


def run(n_rep: int = 10, seed: int = 13):
    n_rep = max(3, int(n_rep * scale()))
    csv = CSV(["figure", "app", "variant", "ccr", "algo", "metric", "mean"])
    rng = np.random.default_rng(seed)
    counts = {"classic": np.zeros(3, int), "medium": np.zeros(3, int)}
    for app, make in GRAPHS.items():
        g = make()
        for variant in ("classic", "medium"):
            for c in CCRS:
                acc: dict = {}
                for _ in range(n_rep):
                    P = int(rng.choice([4, 8, 16]))
                    beta = float(rng.choice(BETAS))
                    if variant == "classic":
                        wl = classic_workload(g, P, c, beta, rng)
                    else:
                        wl = interval_workload(g, P, c, beta, "medium", rng)
                    r = run_algos(wl)
                    counts[variant][cat3(r["ceft_cpl"], r["cpop_cpl"])] += 1
                    for a in ("ceft_cpop", "cpop", "heft"):
                        for metric in ("slr", "speedup"):
                            acc.setdefault((a, metric), []).append(r[a][metric])
                for (a, metric), vals in acc.items():
                    csv.row("fig15_18_realworld", app, variant, c, a, metric,
                            f"{np.mean(vals):.4f}")
    for variant, cats in counts.items():
        pct = 100 * cats / max(cats.sum(), 1)
        csv.row("realworld_cpl_pct", "ALL", variant, "-", "ceft_vs_cpop",
                "longer/equal/shorter",
                f"{pct[0]:.1f}/{pct[1]:.1f}/{pct[2]:.1f}")


if __name__ == "__main__":
    run()
