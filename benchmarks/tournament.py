"""Scheduler tournament (ISSUE 10): every registered planner raced over the
graph zoo, plus the paper's headline misidentification rate.

For each zoo workload (the four RGG weight models x two sizes + the
structure zoo re-weighted with the classic model) every non-exhaustive
planner in ``repro.core.planners`` produces a Plan through the one registry
signature; each Plan is validated as a feasible schedule before its cpl and
makespan land as a CSV row.  On small graphs the exhaustive brute-force
oracle rides along, and CEFT's cpl is asserted >= the oracle's (CEFT missing
the true longest chain would be an algorithm bug, not noise).

The headline: the fraction of experiments where the averaging-based critical
path (CPOP/HEFT's estimate) *misidentifies* the true one — under its own
optimal chain assignment it is strictly shorter than CEFT's critical-path
length (paper §7.3 reports 83.99%).  The rate is computed over the zoo plus
a pool of extra RGG draws and asserted NONZERO, loudly: at any scale, a zero
rate means the predicate or the zoo regressed, because misidentification is
the paper's common case, not a corner.

Timed rows:

* ``jax_csr_tournament`` — the batched CSR sweep planning a zoo graph
  (steady-state, preprocessing excluded), identity-checked against float64
  numpy CEFT (cpl + path) before the timing is reported.  Gated by
  check_regression's ``jax_csr`` prefix.
* ``jax_csr_router_moldable`` — a fresh-plan router tick with the moldable
  fork-join axis enabled (``max_split=4``): the planner sees each class's
  prefill as chunked fork-joins at every power-of-two degree and the router
  keeps the degree whose *realized* schedule finishes first.  Asserted
  in-bench: a degree > 1 wins, and the winning plan's prefill chunks span
  more than one engine — the split demonstrably changes the planned mapping
  (an unsplit prefill is a single task on a single engine).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import ceft, planners, validate_schedule
from repro.core.ceft_jax import ceft_jax_csr, csr_device_inputs, csr_sweep
from repro.core.planners import averaged_path_misidentified, planner_names
from repro.graphs import (classic_workload, fft_graph, gaussian_elimination,
                          heavy_tail_fan_in, rgg, star_fan_in)

from .common import CSV, scale

HEADER = ["bench", "graph", "n", "P", "e", "planner", "cpl", "makespan",
          "avg_path_misid"]

# past this many tasks the oracle's source->sink path enumeration can blow
# the registry's path cap; the zoo's small structures stay well under it
_ORACLE_MAX_N = 48


def _zoo(rng, s: float):
    """Yield (graph_name, Workload): RGG weight models + structure zoo."""
    def sz(n, lo):
        return n if s >= 1.0 else max(lo, int(n * s))

    for kind in ("classic", "low", "medium", "high"):
        for n, P in ((sz(96, 24), 4), (sz(192, 40), 8)):
            yield f"rgg_{kind}", rgg(kind, n, P, rng, o=4, alpha=0.75, beta=50)
    P = 8
    structures = [
        ("realworld_GE", gaussian_elimination(6)),
        ("realworld_FFT", fft_graph(8)),
        ("star", star_fan_in(sz(96, 24))),
        ("heavytail", heavy_tail_fan_in(sz(96, 24), rng)),
    ]
    for name, g in structures:
        yield name, classic_workload(g, P, 1.0, 50, rng)


def run(seed: int = 11, json_rows: list | None = None):
    csv = CSV(HEADER)
    s = scale()
    rng = np.random.default_rng(seed)
    misid = 0
    total = 0
    timed_graphs = []
    for gname, wl in _zoo(rng, s):
        g, comp, m = wl.graph, wl.comp, wl.machine
        n, P = comp.shape
        res = ceft(g, comp, m)
        mis = averaged_path_misidentified(g, comp, m, ceft_result=res)
        misid += int(mis)
        total += 1
        for name in planner_names(include_exhaustive=False):
            spec = planners.get_planner(name)
            p = planners.plan(name, g, comp, m,
                              ceft_result=res if spec.uses_ceft else None)
            validate_schedule(p, g, comp, m)
            csv.row("tournament", gname, n, P, g.n_edges, name,
                    f"{p.cpl:.4f}", f"{p.makespan:.4f}", int(mis))
        if n <= _ORACLE_MAX_N:
            try:
                p = planners.plan("bruteforce", g, comp, m)
            except ValueError:
                p = None  # path enumeration over the cap: skip, don't die
            if p is not None:
                validate_schedule(p, g, comp, m)
                assert res.cpl >= p.cpl - 1e-6 * max(1.0, abs(p.cpl)), (
                    f"CEFT cpl {res.cpl} below the brute-force oracle "
                    f"{p.cpl} on {gname}: CEFT missed the true longest chain")
                csv.row("tournament", gname, n, P, g.n_edges, "bruteforce",
                        f"{p.cpl:.4f}", f"{p.makespan:.4f}", int(mis))
        if (gname in ("rgg_high", "realworld_GE")
                and gname not in [t[0] for t in timed_graphs]):
            timed_graphs.append((gname, g, comp, m, res))

    # extra misid-only draws: the rate is the headline number, so give it a
    # sample bigger than the rendered zoo even at smoke scales
    extra = max(8, int(round(24 * min(1.0, s))))
    for _ in range(extra):
        kind = ("classic", "low", "medium", "high")[total % 4]
        wl = rgg(kind, 32, 4, rng, o=4, alpha=0.75, beta=50)
        misid += int(averaged_path_misidentified(
            wl.graph, wl.comp, wl.machine))
        total += 1
    rate = misid / total
    csv.row("tournament", "misid_rate", total, "-", "-", "avg_path",
            f"{rate:.4f}", "-", misid)
    # the loud gate: the paper reports 83.99% — misidentification is the
    # COMMON case, so a zero count over the whole pool means the predicate,
    # the zoo, or the chain-cost oracle regressed, at any bench scale
    assert misid > 0, (
        f"averaging-based critical path misidentified 0/{total} experiments; "
        "the paper's §7.3 rate is 83.99% — the tournament's misid predicate "
        "or its graph zoo has regressed")
    print(f"# tournament: avg-path misidentification rate {rate:.2%} "
          f"({misid}/{total}; paper §7.3: 83.99%)", flush=True)
    if json_rows is not None:
        json_rows.append({
            "bench": "tournament", "graph": "zoo", "impl":
            "avg_path_misid_rate", "n": int(total), "P": 0, "e": 0,
            "ms": None, "speedup": None, "speedup_vs_padded": None,
            "rate": float(rate), "misid": int(misid),
        })

    _run_timed(csv, timed_graphs, json_rows)
    _run_moldable(csv, seed, json_rows)


def _run_timed(csv: CSV, timed_graphs, json_rows: list | None) -> None:
    """``jax_csr_tournament``: the CSR sweep planning zoo graphs, steady-
    state, identity-checked against float64 numpy CEFT first."""
    for gname, g, comp, m, res in timed_graphs:
        n, P = comp.shape
        res_csr = ceft_jax_csr(g, comp, m)
        assert res_csr.path == res.path and np.isclose(
            res_csr.cpl, res.cpl, rtol=2e-5), (
            f"CSR tournament plan diverged from float64 CEFT on {gname}")
        inputs = csr_device_inputs(g, comp, m)
        out = csr_sweep(inputs)      # compile outside the timed region
        out[0].block_until_ready()
        best = np.inf
        for _ in range(5):
            t0 = time.perf_counter()
            out = csr_sweep(inputs)
            out[0].block_until_ready()
            best = min(best, time.perf_counter() - t0)
        csv.row("tournament", gname, n, P, g.n_edges, "jax_csr_tournament",
                f"{best * 1e3:.3f}", "-", "-")
        if json_rows is not None:
            json_rows.append({
                "bench": "tournament", "graph": gname, "impl":
                "jax_csr_tournament", "n": int(n), "P": int(P),
                "e": int(g.n_edges), "ms": float(best * 1e3),
                "speedup": None, "speedup_vs_padded": None,
            })


def _run_moldable(csv: CSV, seed: int, json_rows: list | None) -> None:
    """``jax_csr_router_moldable``: fresh-plan router tick with the moldable
    split-degree axis on, asserting the split changes the planned mapping."""
    from .serve_router import _make_router, _submit

    P, classes = 4, 3

    def fresh(max_split: int):
        rng = np.random.default_rng(seed)
        router = _make_router(P, classes, rng, max_split=max_split)
        _submit(router, classes, 8, rng)
        router.tick()
        return router

    base = fresh(1)
    mold = fresh(4)
    split = mold.stats["split_degree"]
    assert split > 1, (
        f"moldable router kept split degree {split}: the fork-join axis "
        "never beat the unsplit chain on the bench's heterogeneous pool")
    # the winning plan's realized schedule was memoized during degree
    # selection; an unsplit prefill is ONE task on ONE engine, so chunks
    # landing on >1 distinct engine is the mapping change made observable
    sched = mold._entry.derived["sched"]
    spread = max(
        len(set(int(p) for p in np.asarray(sched.proc)[i * split:
                                                       (i + 1) * split]))
        for i in range(len(mold.last_groups)))
    assert spread > 1, (
        "moldable plan chose a split but every chunk landed on one engine: "
        "the split did not change the planned mapping")
    assert base.stats["split_degree"] == 1 and base.stats[
        "moldable_plans"] == 0, "max_split=1 router touched the moldable path"

    best = np.inf
    dispatches = 0
    for _ in range(5):
        rng = np.random.default_rng(seed)
        router = _make_router(P, classes, rng, max_split=4)
        _submit(router, classes, 8, rng)
        t0 = time.perf_counter()
        ds = router.tick()
        best = min(best, time.perf_counter() - t0)
        dispatches = len(ds)
    n = mold.last_dag[0]
    e = len(mold.last_dag[1])
    csv.row("tournament", f"moldable{split}x", n, P, e,
            "jax_csr_router_moldable", f"{best * 1e3:.3f}",
            f"spread{spread}", dispatches)
    if json_rows is not None:
        json_rows.append({
            "bench": "tournament", "graph": f"moldable{split}x", "impl":
            "jax_csr_router_moldable", "n": int(n), "P": int(P), "e": int(e),
            "ms": float(best * 1e3), "speedup": None,
            "speedup_vs_padded": None, "split_degree": int(split),
            "chunk_engine_spread": int(spread),
        })


if __name__ == "__main__":
    run()
