"""Shared benchmark harness: experiment sampling over the paper's parameter
grids, the per-experiment algorithm battery, CSV emission."""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from repro.core import (
    ceft,
    ceft_cpop,
    ceft_heft_down,
    ceft_heft_up,
    cpop,
    heft,
    slack,
    slr,
    speedup,
)
from repro.core.cpop import cpop_cpl
from repro.graphs import rgg

# the paper's §7.1 grids (sampled rather than exhausted: 345600 experiments
# do not fit a CI box; sizes are scaled by REPRO_BENCH_SCALE)
GRID = {
    "n": [64, 128, 256, 512],
    "P": [2, 4, 8, 16, 32],
    "o": [2, 4, 8],
    "c": [0.001, 0.01, 0.1, 1, 5, 10],
    "alpha": [0.1, 0.25, 0.75, 1.0],
    "beta": [10, 25, 50, 75, 95],
    "gamma": [0.1, 0.25, 0.5, 0.75, 0.95],
}

WORKLOADS = ["classic", "low", "medium", "high"]


def scale() -> float:
    return float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))


def sample_params(rng: np.random.Generator) -> dict:
    return {k: (rng.choice(v) if k != "n" else int(rng.choice(v)))
            for k, v in GRID.items()}


def make_experiment(kind: str, rng: np.random.Generator, **overrides):
    p = sample_params(rng)
    p.update(overrides)
    wl = rgg(kind, int(p["n"]), int(p["P"]), rng, o=float(p["o"]), c=float(p["c"]),
             alpha=float(p["alpha"]), beta=float(p["beta"]), gamma=float(p["gamma"]))
    return wl, p


def run_algos(wl, algos=("ceft_cpop", "cpop", "heft")) -> dict:
    """Returns per-algorithm schedules + CPLs + metrics for one experiment."""
    g, comp, m = wl.graph, wl.comp, wl.machine
    out: dict = {}
    res = ceft(g, comp, m)
    out["ceft_cpl"] = res.cpl
    out["cpop_cpl"] = cpop_cpl(g, comp, m)
    fns = {"ceft_cpop": lambda: ceft_cpop(g, comp, m, res), "cpop": lambda: cpop(g, comp, m),
           "heft": lambda: heft(g, comp, m), "ceft_heft_up": lambda: ceft_heft_up(g, comp, m),
           "ceft_heft_down": lambda: ceft_heft_down(g, comp, m)}
    for name in algos:
        s = fns[name]()
        out[name] = {
            "makespan": s.makespan,
            "speedup": speedup(s, comp, m),
            "slr": slr(s, g, comp),
            "slack": slack(s, g, comp, m),
        }
    return out


def cat3(a: float, b: float, rel: float = 1e-6) -> int:
    """0 longer / 1 equal / 2 shorter (a vs b)."""
    if a > b * (1 + rel):
        return 0
    if a < b * (1 - rel):
        return 2
    return 1


class CSV:
    def __init__(self, header: list[str]):
        self.header = header
        print(",".join(header), flush=True)

    def row(self, *vals):
        print(",".join(str(v) for v in vals), flush=True)


def timed(fn, *args, reps=3):
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        best = min(best, time.perf_counter() - t0)
    return out, best
