"""CEFT scheduler throughput (paper §5 complexity + our §Perf hillclimb).

Four implementations of the same algorithm:
  reference : Algorithm 1 verbatim (4 nested Python loops)  -- paper-faithful
  vectorized: per-task dense (parents x P x P) contraction   -- numpy
  jax_padded: level-batched lax.scan over dense padded tables (O(levels·W·D·P²))
  jax_csr   : edge-centric CSR segment sweep (O(e·P²), bucketed jit shapes)
plus the batched-machines form (vmap over 8 machines -- the online
re-planning shape from repro.sched.straggler).

The irregular rows (star fan-in, heavy-tail in-degree, realworld GE/EW) are
where the dense padding degrades worst; every jax_csr row is checked for
bit-identical values/paths against jax_padded and for matching cpl/path
against the float64 numpy implementation before its timing is reported.

Empirical complexity fit: times regressed against P^2 * e (the paper's
O(P^2 e) claim).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import ceft, ceft_reference, linear_chain
from repro.core.ceft_jax import (
    _sweep,
    _sweep_batch,
    ceft_jax_batch,
    ceft_jax_batch_csr,
    ceft_jax_csr,
    csr_batch_device_inputs,
    csr_batch_sweep,
    csr_device_inputs,
    csr_sweep,
    device_inputs,
)
from repro.graphs import (
    epigenomics,
    gaussian_elimination,
    heavy_tail_fan_in,
    interval_workload,
    rgg,
    star_fan_in,
)

from .common import CSV, scale, timed

HEADER = ["bench", "graph", "n_tasks", "P", "edges", "impl", "ms_per_graph",
          "graphs_per_s", "speedup_vs_reference", "speedup_vs_padded"]


def _steady(fn, reps: int, min_time_s: float = 0.01, batches: int = 3) -> float:
    """Steady-state ms/call: compile, size a rep batch to >= min_time_s, then
    take the best of a few batches.  Sub-ms smoke-scale rows need O(10ms) of
    reps to rise above scheduler noise — with reps=5 the jax_csr-vs-padded
    ratios the CI regression gate diffs were pure jitter."""
    out = fn()  # compile
    out[0].block_until_ready()
    t0 = time.perf_counter()
    out = fn()
    out[0].block_until_ready()
    once = max(time.perf_counter() - t0, 1e-7)
    reps = max(reps, min(200, int(min_time_s / once) + 1))
    best = float("inf")
    for _ in range(batches):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        out[0].block_until_ready()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def _row(csv, json_rows, bench, graph, n, P, e, impl, t, t_ref, t_pad):
    sp_ref = t_ref / t if t == t and t_ref == t_ref else float("nan")
    sp_pad = t_pad / t if t == t and t_pad == t_pad else float("nan")
    csv.row(bench, graph, n, P, e, impl, f"{t * 1e3:.3f}",
            f"{1.0 / t:.1f}" if t == t else "nan",
            f"{sp_ref:.1f}" if sp_ref == sp_ref else "nan",
            f"{sp_pad:.1f}" if sp_pad == sp_pad else "nan")
    if json_rows is not None and t == t:  # NaN timings (skipped impls) stay CSV-only
        json_rows.append({
            "bench": bench, "graph": graph, "impl": impl, "n": int(n),
            "P": int(P), "e": int(e), "ms": float(t * 1e3),
            "speedup": None if sp_ref != sp_ref else float(sp_ref),
            "speedup_vs_padded": None if sp_pad != sp_pad else float(sp_pad),
        })


def _battery(csv, json_rows, bench, graph, g, comp, m, *, ref_limit=1024,
             check_csr=True):
    """Time all four implementations on one workload; returns (e, t_vec)."""
    n, P = comp.shape
    e = g.n_edges
    res_vec, t_vec = timed(lambda: ceft(g, comp, m), reps=2)
    if n <= ref_limit:  # the reference is O(minutes) beyond this
        _, t_ref = timed(lambda: ceft_reference(g, comp, m), reps=1)
    else:
        t_ref = float("nan")

    # padded dense sweep: separate compile from steady-state
    tables, comp_pad, L, bw = device_inputs(g, comp, m)
    t_pad = _steady(lambda: _sweep(tables, comp_pad, L, bw), reps=5)

    # CSR segment sweep, same protocol (preprocessing excluded for both)
    inputs = csr_device_inputs(g, comp, m)
    t_csr = _steady(lambda: csr_sweep(inputs), reps=5)

    if check_csr:
        pad_out = _sweep(tables, comp_pad, L, bw)
        csr_out = csr_sweep(inputs)  # padded carries: slice to n
        for a, b, name in zip(pad_out, csr_out, ["ceft", "ptask", "pproc"]):
            if not np.array_equal(np.asarray(a), np.asarray(b)[:n]):
                raise AssertionError(f"csr/padded {name} mismatch on {graph}")
        res_csr = ceft_jax_csr(g, comp, m)
        if not np.isclose(res_csr.cpl, res_vec.cpl, rtol=2e-5):
            raise AssertionError(f"csr cpl mismatch on {graph}")
        if res_csr.path != res_vec.path:
            raise AssertionError(f"csr path mismatch on {graph}")

    for impl, t in [("reference", t_ref), ("vectorized", t_vec),
                    ("jax_padded", t_pad), ("jax_csr", t_csr)]:
        _row(csv, json_rows, bench, graph, n, P, e, impl, t, t_ref, t_pad)
    return e, t_vec


def run(seed: int = 5, json_rows: list | None = None):
    csv = CSV(HEADER)
    rng = np.random.default_rng(seed)
    s = scale()

    def sz(n, lo=64):
        return n if s >= 1.0 else max(lo, int(n * s))

    # ---- regular level-structured RGGs (the paper's §7.1 shape)
    sizes = [(256, 4), (256, 16), (1024, 16), (1024, 64), (4096, 16)]
    if s < 1.0:
        sizes = [(sz(256), 4), (sz(256), 16), (sz(1024), 16)]
        sizes = list(dict.fromkeys(sizes))  # shrinking can collapse entries
    elif s >= 1.0:
        sizes.append((16384, 64))  # the paper's largest graphs
    fits = []
    for idx, (n, P) in enumerate(sizes):
        wl = rgg("high", n, P, rng, o=4, alpha=0.75, beta=50)
        g, comp, m = wl.graph, wl.comp, wl.machine
        e, t_vec = _battery(csv, json_rows, "ceft_throughput", "rgg_high",
                            g, comp, m)
        fits.append((P * P * e, t_vec))

        if idx == len(sizes) - 1:
            # batched machines (vmap) -- 8 re-planning scenarios at once,
            # dense padded vs shared-segment CSR (the straggler-loop shape)
            B = 8
            comps = np.repeat(comp[None], B, 0).astype(np.float32)
            Ls = np.repeat(np.asarray(m.L, np.float32)[None], B, 0)
            bws = np.repeat(np.asarray(m.bw, np.float32)[None], B, 0)
            # same protocol as the single-graph battery: preprocessing
            # excluded for BOTH sides (prebuilt tables, steady-state sweeps)
            tables, _, _, _ = device_inputs(g, comp, m)
            comp_pad_b = np.concatenate(
                [comps, np.zeros((B, 1, P), np.float32)], axis=1)
            t_batch = _steady(
                lambda: _sweep_batch(tables, comp_pad_b, Ls, bws), reps=3) / B
            _row(csv, json_rows, "ceft_throughput", "rgg_high", n, P, e,
                 "jax_vmap8", t_batch, float("nan"), float("nan"))
            pad_out = ceft_jax_batch(g, comps, Ls, bws)
            csr_out = ceft_jax_batch_csr(g, comps, Ls, bws)
            for a, b, name in zip(pad_out, csr_out, ["ceft", "ptask", "pproc"]):
                if not np.array_equal(np.asarray(a), np.asarray(b)):
                    raise AssertionError(f"batched csr/padded {name} mismatch")
            binputs = csr_batch_device_inputs(g, comps, Ls, bws)
            t_bcsr = _steady(
                lambda: csr_batch_sweep(binputs), reps=3) / B
            _row(csv, json_rows, "ceft_throughput", "rgg_high", n, P, e,
                 "jax_csr_vmap8", t_bcsr, float("nan"), t_batch)

    # ---- irregular fan-in rows: where the dense padding degrades worst
    # (GE is deep and narrow -- regular fan-in -- so it lives with the rgg
    # rows' regime; the irregular set is driven by in-degree skew)
    P = 16
    irregular = [
        ("star", star_fan_in(sz(4000, lo=256))),
        ("heavytail", heavy_tail_fan_in(sz(4000, lo=256), rng)),
        ("realworld_EW", epigenomics(sz(512, lo=48))),
    ]
    for graph_name, g in irregular:
        wl = interval_workload(g, P, 1.0, 50, "high", rng)
        g, comp, m = wl.graph, wl.comp, wl.machine
        _battery(csv, json_rows, "ceft_irregular", graph_name, g, comp, m,
                 ref_limit=600)

    # ---- deep narrow rows (ISSUE 4): chains and GE-like graphs are where the
    # per-level Python dispatch used to lose to the dense scan at small n; the
    # fused same-bucket super-steps collapse them to O(1)/O(log) dispatches
    deep = [
        ("chain", linear_chain(sz(256, lo=64))),
        ("realworld_GE", gaussian_elimination(max(6, int(22 * min(1.0, s + 0.5))))),
    ]
    for graph_name, g in deep:
        wl = interval_workload(g, P, 1.0, 50, "high", rng)
        g, comp, m = wl.graph, wl.comp, wl.machine
        _battery(csv, json_rows, "ceft_deep", graph_name, g, comp, m,
                 ref_limit=600)

    # O(P^2 e) scaling fit on the vectorized impl
    x = np.log(np.asarray([f[0] for f in fits], float))
    y = np.log(np.asarray([f[1] for f in fits], float))
    slope = float(np.polyfit(x, y, 1)[0])
    csv.row("ceft_complexity_fit", "-", "-", "-", "-", "log-log slope vs P^2*e",
            f"{slope:.3f}", "expect ~<= 1", "-", "-")


if __name__ == "__main__":
    run()
