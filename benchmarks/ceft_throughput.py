"""CEFT scheduler throughput (paper §5 complexity + our §Perf hillclimb).

Three implementations of the same algorithm:
  reference : Algorithm 1 verbatim (4 nested Python loops)  -- paper-faithful
  vectorized: per-task dense (parents x P x P) contraction   -- numpy
  jax       : level-batched lax.scan sweep (jit, the TPU formulation)
plus the batched-machines form (vmap over 8 machines -- the online
re-planning shape from repro.sched.straggler).

Empirical complexity fit: times regressed against P^2 * e (the paper's
O(P^2 e) claim).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import ceft, ceft_reference
from repro.core.ceft_jax import _sweep, ceft_jax, ceft_jax_batch, device_inputs
from repro.graphs import rgg

from .common import CSV, scale, timed


def run(seed: int = 5):
    csv = CSV(["bench", "n_tasks", "P", "edges", "impl", "ms_per_graph",
               "graphs_per_s", "speedup_vs_reference"])
    rng = np.random.default_rng(seed)
    sizes = [(256, 4), (256, 16), (1024, 16), (1024, 64), (4096, 16)]
    if scale() >= 1.0:
        sizes.append((16384, 64))  # the paper's largest graphs
    fits = []
    for n, P in sizes:
        wl = rgg("high", n, P, rng, o=4, alpha=0.75, beta=50)
        g, comp, m = wl.graph, wl.comp, wl.machine
        e = g.n_edges

        if n <= 1024:  # the reference is O(minutes) beyond this
            _, t_ref = timed(lambda: ceft_reference(g, comp, m), reps=1)
        else:
            t_ref = float("nan")
        _, t_vec = timed(lambda: ceft(g, comp, m), reps=2)

        # jax: separate compile from steady-state
        tables, comp_pad, L, bw = device_inputs(g, comp, m)
        out = _sweep(tables, comp_pad, L, bw)  # compile
        out[0].block_until_ready()
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            out = _sweep(tables, comp_pad, L, bw)
        out[0].block_until_ready()
        t_jax = (time.perf_counter() - t0) / reps

        # batched machines (vmap) -- 8 re-planning scenarios at once
        B = 8
        comps = np.repeat(comp[None], B, 0)
        Ls = np.repeat(np.asarray(m.L, np.float32)[None], B, 0)
        bws = np.repeat(np.asarray(m.bw, np.float32)[None], B, 0)
        outb = ceft_jax_batch(g, comps, Ls, bws)  # compile
        outb[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            outb = ceft_jax_batch(g, comps, Ls, bws)
        outb[0].block_until_ready()
        t_batch = (time.perf_counter() - t0) / 3 / B

        for impl, t in [("reference", t_ref), ("vectorized", t_vec),
                        ("jax", t_jax), ("jax_vmap8", t_batch)]:
            csv.row("ceft_throughput", n, P, e, impl, f"{t * 1e3:.2f}",
                    f"{1.0 / t:.1f}" if t == t else "nan",
                    f"{t_ref / t:.1f}" if t == t and t_ref == t_ref else "nan")
        fits.append((P * P * e, t_vec))

    # O(P^2 e) scaling fit on the vectorized impl
    x = np.log(np.asarray([f[0] for f in fits], float))
    y = np.log(np.asarray([f[1] for f in fits], float))
    slope = float(np.polyfit(x, y, 1)[0])
    csv.row("ceft_complexity_fit", "-", "-", "-", "log-log slope vs P^2*e",
            f"{slope:.3f}", "expect ~<= 1", "-")


if __name__ == "__main__":
    run()
