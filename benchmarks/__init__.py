# Benchmark battery -- see run.py
