"""Benchmark battery: one module per paper table/figure (+ beyond-paper
benches).  Each prints CSV to stdout; `python -m benchmarks.run` runs all.

  REPRO_BENCH_SCALE=0.25 python -m benchmarks.run     # quick pass
  python -m benchmarks.run --only table3 sweeps       # subset
"""
import argparse
import sys
import time


def main() -> None:
    from . import (ceft_throughput, kernel_bench, partitioner_bench,
                   realworld, sweeps, table3)
    suites = {
        "table3": table3.run,                      # Table 3 + Figs 5-6
        "sweeps": sweeps.run,                      # Figs 10-14
        "ranks": lambda: sweeps.run(ranks=True, n_rep=6),   # Figs 19-20 (§8.2)
        "realworld": realworld.run,                # Figs 15-18
        "ceft_throughput": ceft_throughput.run,    # §5 complexity / §Perf
        "kernel": kernel_bench.run,                # kernel layer
        "partitioner": partitioner_bench.run,      # beyond-paper
    }
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=list(suites))
    args = ap.parse_args()
    names = args.only or list(suites)
    for name in names:
        print(f"\n# ==== {name} ====", flush=True)
        t0 = time.time()
        suites[name]()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == '__main__':
    main()
