"""Benchmark battery: one module per paper table/figure (+ beyond-paper
benches).  Each prints CSV to stdout; `python -m benchmarks.run` runs all.

  REPRO_BENCH_SCALE=0.25 python -m benchmarks.run     # quick pass
  python -m benchmarks.run --only table3 sweeps       # subset
  python -m benchmarks.run --only ceft_throughput --json BENCH_ceft.json

--json mirrors the CEFT-throughput CSV rows into a machine-readable perf
trajectory file (schema: {"schema", "scale", "rows": [{impl, n, P, e, ms,
speedup, planner, ...}]}) so future perf PRs have a baseline to diff against;
CI refreshes it on every pass (scripts/ci.sh).  The serve_router suite also
mirrors its gated per-tick rows (jax_csr_router, jax_csr_router_steady) and
the registry-checked heft_router row; the tournament suite mirrors its CSR
planning rows, the moldable-router row, and the misidentification rate.
Every row carries the planner that produced it (default ceft_cpop).
"""
import argparse
import json
import sys
import time


def main() -> None:
    from . import (ceft_throughput, kernel_bench, partitioner_bench,
                   realworld, serve_router, sweeps, table3, tournament)
    from .common import scale
    suites = {
        "table3": table3.run,                      # Table 3 + Figs 5-6
        "sweeps": sweeps.run,                      # Figs 10-14
        "ranks": lambda: sweeps.run(ranks=True, n_rep=6),   # Figs 19-20 (§8.2)
        "realworld": realworld.run,                # Figs 15-18
        "ceft_throughput": ceft_throughput.run,    # §5 complexity / §Perf
        "serve_router": serve_router.run,          # router tick throughput
        "tournament": tournament.run,              # planner registry race (§7.3)
        "kernel": kernel_bench.run,                # kernel layer
        "partitioner": partitioner_bench.run,      # beyond-paper
    }
    # suites whose run() mirrors rows into the --json trajectory file
    json_suites = {"ceft_throughput": ceft_throughput.run,
                   "serve_router": serve_router.run,
                   "tournament": tournament.run}
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=list(suites))
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write machine-readable perf rows (BENCH_ceft.json)")
    args = ap.parse_args()
    names = args.only or list(suites)
    json_rows: list = []
    for name in names:
        print(f"\n# ==== {name} ====", flush=True)
        t0 = time.time()
        if args.json and name in json_suites:
            json_suites[name](json_rows=json_rows)
        else:
            suites[name]()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if args.json and not json_rows:
        # don't clobber an existing baseline when the selected suites mirror
        # nothing (e.g. --only sweeps --json ...)
        print(f"# no JSON-mirroring suite selected; {args.json} not written",
              flush=True)
    elif args.json:
        import jax  # record the producing version: the CI gate pins the range
        from repro.substrate import process_topology

        # ISSUE 10: every perf row names the planner that produced it, so a
        # future planner-default change cannot silently redefine a baseline
        for r in json_rows:
            r.setdefault("planner", "ceft_cpop")

        # where the rows were produced (ISSUE 7): perf numbers are only
        # comparable on like hardware, so the host/worker topology rides in
        # the metadata.  The volatile pid is dropped -- the file must not
        # churn between identical runs on the same box.
        topo = {k: v for k, v in process_topology().items() if k != "pid"}
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "scale": scale(),
                       "jax_version": jax.__version__, "topology": topo,
                       "rows": json_rows},
                      f, indent=2)
            f.write("\n")
        print(f"# wrote {len(json_rows)} rows to {args.json}", flush=True)


if __name__ == '__main__':
    main()
