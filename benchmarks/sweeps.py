"""Paper figure sweeps:

  fig10/12/19: mean speedup vs #processors / beta / alpha (per algorithm)
  fig11/13/14/20: mean SLR vs beta / alpha / CCR / #tasks
  fig13c: mean slack vs CCR
  --ranks adds the CEFT-HEFT-UP/DOWN variants (paper §8.2)
"""
from __future__ import annotations

import numpy as np

from .common import CSV, WORKLOADS, make_experiment, run_algos, scale

BASE_ALGOS = ("ceft_cpop", "cpop", "heft")
RANK_ALGOS = BASE_ALGOS + ("ceft_heft_up", "ceft_heft_down")


def _sweep(csv: CSV, fig: str, kind: str, param: str, values, rng, n_rep, algos):
    for val in values:
        acc: dict[str, dict[str, list[float]]] = {a: {} for a in algos}
        for _ in range(n_rep):
            wl, _ = make_experiment(kind, rng, **{param: val})
            r = run_algos(wl, algos=algos)
            for a in algos:
                for metric in ("speedup", "slr", "slack", "makespan"):
                    acc[a].setdefault(metric, []).append(r[a][metric])
        for a in algos:
            for metric in ("speedup", "slr", "slack"):
                csv.row(fig, kind, param, val, a, metric,
                        f"{np.mean(acc[a][metric]):.4f}")


def run(n_rep: int = 12, seed: int = 11, ranks: bool = False):
    n_rep = max(3, int(n_rep * scale()))
    algos = RANK_ALGOS if ranks else BASE_ALGOS
    csv = CSV(["figure", "workload", "param", "value", "algo", "metric", "mean"])
    rng = np.random.default_rng(seed)
    # fig 10: speedup vs number of processors (all four workloads)
    for kind in WORKLOADS:
        _sweep(csv, "fig10_speedup_vs_P", kind, "P", [2, 4, 8, 16, 32], rng, n_rep, algos)
    # figs 11/12: SLR & speedup vs beta (heterogeneity)
    for kind in WORKLOADS:
        _sweep(csv, "fig11_12_vs_beta", kind, "beta", [10, 25, 50, 75, 95], rng, n_rep, algos)
    # figs 13a/19/20: vs alpha (graph width)
    for kind in ("classic", "high"):
        _sweep(csv, "fig13_19_20_vs_alpha", kind, "alpha", [0.1, 0.25, 0.75, 1.0], rng, n_rep, algos)
    # figs 13b/13c: vs CCR
    for kind in ("classic", "high"):
        _sweep(csv, "fig13_vs_ccr", kind, "c", [0.01, 0.1, 1, 5, 10], rng, n_rep, algos)
    # fig 14: vs number of tasks
    for kind in ("classic", "high"):
        _sweep(csv, "fig14_vs_tasks", kind, "n", [64, 128, 256, 512], rng, n_rep, algos)


if __name__ == "__main__":
    run()
