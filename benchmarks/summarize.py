"""Summarize bench_output.txt CSV into the paper's figures as markdown tables.

  PYTHONPATH=src python -m benchmarks.summarize bench_output.txt [--fig fig10]
"""
from __future__ import annotations

import argparse
import collections
import sys


def load(path: str):
    rows = []
    for line in open(path):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        rows.append(line.split(","))
    return rows


def fig_table(rows, figure: str, metric: str, workloads=None):
    """Pivot: rows = sweep value, columns = algorithm, cells = mean metric."""
    data = collections.defaultdict(dict)
    algos = []
    param = None
    for r in rows:
        if len(r) < 7 or r[0] != figure or r[5] != metric:
            continue
        _, kind, param, value, algo, _, mean = r[:7]
        if workloads and kind not in workloads:
            continue
        key = (kind, value)
        data[key][algo] = float(mean)
        if algo not in algos:
            algos.append(algo)
    if not data:
        return f"(no rows for {figure}/{metric})"
    out = [f"**{figure} — mean {metric}**", ""]
    out.append("| workload | " + (param or "x") + " | " + " | ".join(algos) + " |")
    out.append("|---" * (len(algos) + 2) + "|")
    for (kind, value), per in data.items():
        cells = [f"{per.get(a, float('nan')):.3f}" for a in algos]
        out.append(f"| {kind} | {value} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def tournament(rows):
    """Scheduler-tournament pivot: one row per zoo graph, one cpl column per
    planner (ISSUE 10), followed by the misidentification headline and the
    timed (jax_csr*) rows."""
    data = collections.defaultdict(dict)
    planners: list[str] = []
    timed_rows = []
    rate = None
    for r in rows:
        if r[0] != "tournament" or len(r) < 9:
            continue
        _, graph, n, P, e, planner, cpl, makespan, mis = r[:9]
        if graph == "misid_rate":
            rate = (cpl, mis, n)
        elif planner.startswith("jax_csr"):
            timed_rows.append((graph, planner, cpl, makespan))
        else:
            key = (graph, n, P)
            data[key][planner] = cpl
            if planner not in planners:
                planners.append(planner)
    if not data and rate is None:
        return "(no rows for tournament)"
    out = ["**Scheduler tournament — critical-path length per planner**", "",
           "| graph | n | P | " + " | ".join(planners) + " |",
           "|---" * (len(planners) + 3) + "|"]
    for (graph, n, P), per in data.items():
        cells = [per.get(p, "-") for p in planners]
        out.append(f"| {graph} | {n} | {P} | " + " | ".join(cells) + " |")
    if rate is not None:
        out += ["", f"Averaging-based path misidentified in {rate[1]}/{rate[2]}"
                    f" experiments (rate {rate[0]}; paper §7.3: 83.99%)."]
    for graph, planner, ms, extra in timed_rows:
        out.append(f"- `{planner}` on {graph}: {ms} ms"
                   + (f" ({extra})" if extra != "-" else ""))
    return "\n".join(out)


def other_families(rows, known: set):
    """One line per CSV family the named renderers do not cover — unknown
    families are surfaced with row counts, never silently dropped."""
    # r[0] == "bench" is a CSV header line, not a family
    counts = collections.Counter(
        r[0] for r in rows if r[0] not in known and r[0] != "bench")
    if not counts:
        return None
    out = ["**Other bench families (raw CSV, no dedicated renderer)**", ""]
    for fam, k in sorted(counts.items()):
        out.append(f"- {fam}: {k} row(s)")
    return "\n".join(out)


def table3(rows):
    out = ["**Table 3 — CEFT(-CPOP) vs CPOP, longer/equal/shorter %**", "",
           "| workload | quantity | longer | equal | shorter |",
           "|---|---|---|---|---|"]
    for r in rows:
        if r[0] == "table3":
            out.append(f"| {r[1]} | {r[2]} | {r[3]} | {r[4]} | {r[5]} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("csv", nargs="?", default="bench_output.txt")
    ap.add_argument("--fig", default=None)
    args = ap.parse_args()
    rows = load(args.csv)
    sections = [table3(rows)]
    figures = [
        ("fig10_speedup_vs_P", "speedup", None),
        ("fig11_12_vs_beta", "slr", ("medium", "high")),
        ("fig11_12_vs_beta", "speedup", ("medium", "high")),
        ("fig13_19_20_vs_alpha", "slr", None),
        ("fig13_vs_ccr", "slr", None),
        ("fig13_vs_ccr", "slack", None),
        ("fig14_vs_tasks", "slr", None),
    ]
    for figure, metric, wl in figures:
        if args.fig and not figure.startswith(args.fig):
            continue
        sections.append(fig_table(rows, figure, metric, wl))
    if not args.fig or "tournament".startswith(args.fig):
        sections.append(tournament(rows))
    known = {"table3", "tournament"} | {f for f, _, _ in figures}
    extra = other_families(rows, known)
    if extra is not None and not args.fig:
        sections.append(extra)
    print("\n\n".join(sections))


if __name__ == "__main__":
    main()
