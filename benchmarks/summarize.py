"""Summarize bench_output.txt CSV into the paper's figures as markdown tables.

  PYTHONPATH=src python -m benchmarks.summarize bench_output.txt [--fig fig10]
"""
from __future__ import annotations

import argparse
import collections
import sys


def load(path: str):
    rows = []
    for line in open(path):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        rows.append(line.split(","))
    return rows


def fig_table(rows, figure: str, metric: str, workloads=None):
    """Pivot: rows = sweep value, columns = algorithm, cells = mean metric."""
    data = collections.defaultdict(dict)
    algos = []
    param = None
    for r in rows:
        if len(r) < 7 or r[0] != figure or r[5] != metric:
            continue
        _, kind, param, value, algo, _, mean = r[:7]
        if workloads and kind not in workloads:
            continue
        key = (kind, value)
        data[key][algo] = float(mean)
        if algo not in algos:
            algos.append(algo)
    if not data:
        return f"(no rows for {figure}/{metric})"
    out = [f"**{figure} — mean {metric}**", ""]
    out.append("| workload | " + (param or "x") + " | " + " | ".join(algos) + " |")
    out.append("|---" * (len(algos) + 2) + "|")
    for (kind, value), per in data.items():
        cells = [f"{per.get(a, float('nan')):.3f}" for a in algos]
        out.append(f"| {kind} | {value} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def table3(rows):
    out = ["**Table 3 — CEFT(-CPOP) vs CPOP, longer/equal/shorter %**", "",
           "| workload | quantity | longer | equal | shorter |",
           "|---|---|---|---|---|"]
    for r in rows:
        if r[0] == "table3":
            out.append(f"| {r[1]} | {r[2]} | {r[3]} | {r[4]} | {r[5]} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("csv", nargs="?", default="bench_output.txt")
    ap.add_argument("--fig", default=None)
    args = ap.parse_args()
    rows = load(args.csv)
    sections = [table3(rows)]
    for figure, metric, wl in [
        ("fig10_speedup_vs_P", "speedup", None),
        ("fig11_12_vs_beta", "slr", ("medium", "high")),
        ("fig11_12_vs_beta", "speedup", ("medium", "high")),
        ("fig13_19_20_vs_alpha", "slr", None),
        ("fig13_vs_ccr", "slr", None),
        ("fig13_vs_ccr", "slack", None),
        ("fig14_vs_tasks", "slr", None),
    ]:
        if args.fig and not figure.startswith(args.fig):
            continue
        sections.append(fig_table(rows, figure, metric, wl))
    print("\n\n".join(sections))


if __name__ == "__main__":
    main()
