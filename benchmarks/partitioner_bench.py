"""Beyond-paper benchmark: CEFT as the runtime's pipeline partitioner on the
ten assigned architectures (layer DAGs x heterogeneous fleets), nominal and
degraded (straggler) scenarios."""
from __future__ import annotations

import time

import numpy as np

import repro.configs as C
from repro.configs.base import SHAPES
from repro.core import ceft, ceft_cpop, cpop, heft
from repro.sched import DEFAULT_FLEET, DeviceClass, build_layer_dag, plan_pipeline

from .common import CSV

CONSTRAINED = [
    DeviceClass("v5e-96", 96 * 197e12, 96 * 819e9, 50e9, 2),
    DeviceClass("v5p-32", 32 * 459e12, 32 * 2765e9, 90e9, 2),
    DeviceClass("v5e-48-degraded", 48 * 197e12, 48 * 819e9, 25e9, 2),
    DeviceClass("host-cpu", 3e12, 100e9, 12.5e9, 4),
]


def run():
    csv = CSV(["bench", "arch", "cell", "fleet", "cpl_ms", "ceft_cpop_ms",
               "cpop_ms", "heft_ms", "vs_cpop", "plan_ms"])
    for arch in C.ARCHS:
        cfg = C.get(arch)
        for cell_name in ("train_4k", "decode_32k"):
            cell = SHAPES[cell_name]
            for fleet_name, fleet in (("default", None), ("constrained", CONSTRAINED)):
                t0 = time.perf_counter()
                plan = plan_pipeline(cfg, cell, fleet=fleet)
                dt = time.perf_counter() - t0
                csv.row("partitioner", arch, cell_name, fleet_name,
                        f"{plan.cpl * 1e3:.3f}", f"{plan.makespan * 1e3:.3f}",
                        f"{plan.makespan_cpop * 1e3:.3f}",
                        f"{plan.makespan_heft * 1e3:.3f}",
                        f"{plan.speedup_vs_cpop:.3f}", f"{dt * 1e3:.1f}")

    # straggler scenario: degrade each class 3x in turn (glm4 train DAG)
    g, comp, m, _ = build_layer_dag(C.get("glm4-9b"), SHAPES["train_4k"])
    base = ceft_cpop(g, comp, m, ceft(g, comp, m)).makespan
    for cls in range(m.P):
        degraded = comp.copy()
        degraded[:, cls] *= 3.0
        ours = ceft_cpop(g, degraded, m, ceft(g, degraded, m)).makespan
        cp = cpop(g, degraded, m).makespan
        hf = heft(g, degraded, m).makespan
        csv.row("straggler_replan", "glm4-9b", "train_4k", f"class{cls}x3",
                "-", f"{ours * 1e3:.3f}", f"{cp * 1e3:.3f}", f"{hf * 1e3:.3f}",
                f"{cp / ours:.3f}", f"{base * 1e3:.3f}")


if __name__ == "__main__":
    run()
