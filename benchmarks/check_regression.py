"""Perf-regression gate over BENCH_ceft.json (ISSUE 4).

Diffs a freshly produced trajectory file against the committed baseline and
fails on a real slowdown of the gated implementation's rows, turning
BENCH_ceft.json from an advisory artifact into an enforced trajectory:

    python -m benchmarks.check_regression BASELINE FRESH \
        [--impl jax_csr] [--threshold 2.0] [--abs-floor-ms 0.5]

Rows are matched on (bench, graph, impl, n, P, e).  A fresh row fails when it
is more than ``threshold`` x its baseline AND the absolute slowdown exceeds
``abs_floor_ms`` — smoke-scale rows are sub-millisecond, where a 2x blip is
scheduler noise, not a regression.  Rows absent from the baseline are skipped
(new benches never fail the gate), but zero matched rows is itself a failure
(a renamed bench must not silently disarm the gate).  A scale mismatch between
the two files is a hard failure: cross-scale timings are not comparable, so
the committed baseline must be regenerated at the new scale.
"""
from __future__ import annotations

import argparse
import json
import sys


def _key(row: dict) -> tuple:
    return (row.get("bench"), row.get("graph"), row.get("impl"),
            row.get("n"), row.get("P"), row.get("e"))


def check(baseline: dict, fresh: dict, *, impl: str = "jax_csr",
          threshold: float = 2.0, abs_floor_ms: float = 0.5) -> list[str]:
    """Returns the list of failure messages (empty == gate passes).

    ``impl`` matches by prefix so the gate covers the whole implementation
    family — ``--impl jax_csr`` gates ``jax_csr`` AND ``jax_csr_vmap8`` (the
    batched re-planning row), not just the exact string."""
    if baseline.get("scale") != fresh.get("scale"):
        return [f"scale mismatch: baseline {baseline.get('scale')} vs fresh "
                f"{fresh.get('scale')} -- regenerate the committed baseline"]
    base_ms = {_key(r): r["ms"] for r in baseline.get("rows", [])
               if str(r.get("impl", "")).startswith(impl)}
    failures: list[str] = []
    matched = 0
    for row in fresh.get("rows", []):
        if not str(row.get("impl", "")).startswith(impl):
            continue
        k = _key(row)
        if k not in base_ms:  # new bench/graph: no baseline to regress against
            continue
        matched += 1
        old, new = base_ms[k], row["ms"]
        if new > threshold * old and new - old > abs_floor_ms:
            failures.append(
                f"{row['bench']}/{row['graph']} (n={row['n']}, P={row['P']}): "
                f"{old:.3f}ms -> {new:.3f}ms ({new / old:.2f}x > {threshold}x)")
    if matched == 0:
        failures.append(
            f"no fresh '{impl}' rows matched the baseline -- the gate is "
            "disarmed; regenerate the committed BENCH_ceft.json")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_ceft.json")
    ap.add_argument("fresh", help="freshly produced BENCH_ceft.json")
    ap.add_argument("--impl", default="jax_csr")
    ap.add_argument("--threshold", type=float, default=2.0)
    ap.add_argument("--abs-floor-ms", type=float, default=0.5)
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures = check(baseline, fresh, impl=args.impl, threshold=args.threshold,
                     abs_floor_ms=args.abs_floor_ms)
    if failures:
        print(f"check_regression: FAIL ({len(failures)} finding(s)):")
        for msg in failures:
            print(f"  {msg}")
        return 1
    n = sum(1 for r in fresh.get("rows", [])
            if str(r.get("impl", "")).startswith(args.impl))
    print(f"check_regression: OK -- {n} '{args.impl}*' row(s) within "
          f"{args.threshold}x of the baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
