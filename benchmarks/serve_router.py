"""Serving-router planning throughput (ISSUE 5).

Measures the CEFT-routed front-end's per-tick cost — drain + request-DAG
build + fused CSR sweep + micro-batch formation — on fake engines (no model
math: this is the dispatch-policy overhead a serving tier pays per tick).
The steady-state tick hits the one-slot request-graph cache, so what is
timed is the real recurring work: cost-plane build + one bucketed sweep.

Every timed row is identity-checked first: the router's planned critical
path must match the dense padded sweep (bit-identical family guarantee) and
the float64 numpy CEFT on the same DAG.  The ``jax_csr_router`` and
``jax_csr_router_steady`` rows land in BENCH_ceft.json and are covered by
benchmarks.check_regression's ``--impl jax_csr`` prefix gate.

The steady row (ISSUE 6) measures the incremental-admission guarantee: a
budgeted tick whose resident mix matches the cached plan serves it straight
from the plan cache — no cost-plane build, no sweep — so its latency must be
flat in the resident count (asserted in-bench: 8x residents <= 1.25x the 1x
latency).  A classic-HEFT comparison row (``heft_router``) goes through the
planner registry (ISSUE 10) and is checked: the registry Plan must match a
direct ``heft()`` call instance for instance and validate as a feasible
schedule; its planner name rides in the row metadata.

The SLO rows (ISSUE 9) measure what the weighted admission tiers buy a
high-tier tenant under an adversarial low-tier flood: 8 flooding tenants
submit first, one gold tenant (weight 8, with an SLO) submits last, all in
the SAME workload class so the queue's drain order alone decides dispatch
order.  ``jax_csr_router_slo`` records the gold tenant's P99
submit-to-dispatch sojourn tiered vs untiered, asserted better (+0.2ms
noise floor) tiered — and identity-checked first: uniform tier weights must
reproduce the untiered insertion-order round-robin drain pop for pop.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import ceft, heft, planners, validate_schedule
from repro.core.ceft_jax import ceft_jax
from repro.serve import (AdmissionQueue, EnginePool, EngineSlot, Request,
                         Router, TenantTier, WorkerSpec)

from .common import CSV, scale, timed

HEADER = ["bench", "pool", "n_nodes", "P", "edges", "impl", "ms_per_tick",
          "ticks_per_s", "dispatches"]


class _NullEngine:
    """Cheapest possible pool member: routing overhead only."""

    def generate(self, prompts, scfg):
        B, P = prompts.shape
        return np.zeros((B, P + scfg.max_new_tokens), np.int32)


def _make_router(P: int, classes: int, rng, **kw) -> Router:
    slots = [EngineSlot(f"e{i}", _NullEngine(), "baseline") for i in range(P)]
    router = Router(slots, max_batch=8, **kw)
    # pre-seeded heterogeneous per-token rates: ties would make the plan
    # degenerate (every class argmins to engine 0) and unrepresentative
    for c in range(classes):
        wc = (1 << (3 + c), 8)
        for e in range(P):
            router.costs.update(wc, e, float(rng.uniform(0.5e-3, 2e-3)))
    return router


def _submit(router: Router, classes: int, per_class: int, rng) -> None:
    for c in range(classes):
        plen = 1 << (3 + c)
        for k in range(per_class):
            prompt = rng.integers(2, 100, plen).astype(np.int32)
            router.submit(Request(f"t{c}", prompt, 8))


def run(seed: int = 7, json_rows: list | None = None):
    csv = CSV(HEADER)
    s = scale()
    per_class = max(2, int(round(32 * s)))
    for P, classes in ((2, 2), (4, 4), (8, 6)):
        rng = np.random.default_rng(seed)
        router = _make_router(P, classes, rng)

        def one_tick():
            _submit(router, classes, per_class, rng)
            return router.tick()

        def timed_ticks(reps: int) -> float:
            """Best-of per-tick seconds with submission kept OUT of the timed
            region (the gated row measures drain + DAG build + sweep +
            micro-batch formation only, as documented)."""
            best = np.inf
            for _ in range(reps):
                _submit(router, classes, per_class, rng)
                t0 = time.perf_counter()
                router.tick()
                best = min(best, time.perf_counter() - t0)
            return best

        dispatches = len(one_tick())  # compile + warm the request-graph cache
        n, src, dst, data, comp = router.last_dag
        res = router.last_plan
        # identity gate: the router's plan == dense padded sweep (bit-identical
        # family) == float64 numpy CEFT on the same DAG
        ref = ceft_jax(_graph(n, src, dst, data), comp, router.machine)
        assert np.array_equal(res.ceft, ref.ceft) and res.path == ref.path, \
            "router plan diverged from the dense padded sweep"
        f64 = ceft(_graph(n, src, dst, data), comp, router.machine)
        assert f64.path == res.path and abs(f64.cpl - res.cpl) <= 1e-5 * max(
            1.0, abs(f64.cpl)), "router plan diverged from float64 CEFT"
        t = timed_ticks(reps=15)  # best-of: the 2x CI gate needs a steady
        # number, and a single tick is ~ms (scheduler-noise sized)
        csv.row("serve_router", f"pool{P}", n, P, len(src), "jax_csr_router",
                f"{t * 1e3:.3f}", f"{1.0 / t:.1f}", dispatches)
        if json_rows is not None:
            json_rows.append({
                "bench": "serve_router", "graph": f"pool{P}", "impl":
                "jax_csr_router", "n": int(n), "P": int(P), "e": int(len(src)),
                "ms": float(t * 1e3), "speedup": None,
                "speedup_vs_padded": None,
            })
        # float64 numpy CEFT on the same DAG for context (not gated)
        _, t_np = timed(lambda: ceft(_graph(n, src, dst, data), comp,
                                     router.machine), reps=3)
        csv.row("serve_router", f"pool{P}", n, P, len(src), "vectorized",
                f"{t_np * 1e3:.3f}", f"{1.0 / t_np:.1f}", dispatches)
        # classic HEFT on the same DAG, now through the planner registry
        # (ISSUE 10): checked, not a context curiosity — the registry Plan
        # must reproduce a direct heft() call instance for instance and
        # validate as a feasible schedule before its timing lands
        gg = _graph(n, src, dst, data)
        p_heft = planners.plan("heft", gg, comp, router.machine)
        direct = heft(gg, comp, router.machine)
        assert np.array_equal(p_heft.proc, direct.proc) and np.array_equal(
            p_heft.finish, direct.finish), \
            "registry heft plan diverged from a direct heft() call"
        validate_schedule(p_heft, gg, comp, router.machine)
        _, t_heft = timed(
            lambda: planners.plan("heft", gg, comp, router.machine), reps=3)
        csv.row("serve_router", f"pool{P}", n, P, len(src), "heft_router",
                f"{t_heft * 1e3:.3f}", f"{1.0 / t_heft:.1f}", dispatches)
        if json_rows is not None:
            json_rows.append({
                "bench": "serve_router", "graph": f"pool{P}", "impl":
                "heft_router", "n": int(n), "P": int(P), "e": int(len(src)),
                "ms": float(t_heft * 1e3), "speedup": None,
                "speedup_vs_padded": None, "planner": "heft",
            })
    _run_steady(csv, seed, per_class, json_rows)
    _run_scaleout(csv, seed, per_class, json_rows)
    _run_hedge(csv, seed, per_class, json_rows)
    _run_slo(csv, seed, per_class, json_rows)


def _refill(router: Router, ds, rng) -> None:
    """Resubmit exactly what a tick dispatched, class for class, so the
    resident mix (and therefore the plan signature) is unchanged."""
    for d in ds:
        plen, max_new = d.wclass
        for _ in d.requests:
            prompt = rng.integers(2, 100, plen).astype(np.int32)
            router.submit(Request("steady", prompt, max_new))


def _run_steady(csv: CSV, seed: int, per_class: int,
                json_rows: list | None) -> None:
    """ISSUE 6: steady-state budgeted tick latency at 1x vs 8x residents.

    Each timed tick is a plan-cache short-circuit (same mix, no cost delta):
    drain + signature check + micro-batch formation for ``budget`` requests,
    O(classes + budget) work independent of the resident count.  Refill
    happens OUTSIDE the timed region."""
    P, classes, budget = 4, 4, 4
    ms = {}
    for mult in (1, 8):
        rng = np.random.default_rng(seed)
        router = _make_router(P, classes, rng)
        router.tick_budget = budget
        _submit(router, classes, per_class * mult, rng)
        ds = router.tick()                    # warm: the one real plan
        _refill(router, ds, rng)
        best = np.inf
        for _ in range(30):
            t0 = time.perf_counter()
            ds = router.tick()
            best = min(best, time.perf_counter() - t0)
            _refill(router, ds, rng)
        assert router.stats["plans"] == 1, \
            "steady ticks re-planned: the cache short-circuit regressed"
        assert router.stats["cache_hits"] >= 30
        n = per_class * mult * classes
        ms[mult] = best
        csv.row("serve_router", f"res{mult}x", n, P, 0,
                "jax_csr_router_steady", f"{best * 1e3:.3f}",
                f"{1.0 / best:.1f}", len(ds))
        if json_rows is not None:
            json_rows.append({
                "bench": "serve_router", "graph": f"res{mult}x", "impl":
                "jax_csr_router_steady", "n": int(n), "P": int(P), "e": 0,
                "ms": float(best * 1e3), "speedup": None,
                "speedup_vs_padded": None,
            })
    # the flatness guarantee itself (0.2ms absolute floor absorbs timer noise
    # at smoke scales where a tick is tens of microseconds)
    assert ms[8] <= 1.25 * ms[1] + 2e-4, (
        f"steady tick is not flat in residents: {ms[1] * 1e3:.3f}ms @1x vs "
        f"{ms[8] * 1e3:.3f}ms @8x")


def _run_scaleout(csv: CSV, seed: int, per_class: int,
                  json_rows: list | None) -> None:
    """ISSUE 7: per-tick planning latency through the elastic EnginePool at
    1 vs 4 workers (null engines: pool + routing overhead only).  The
    4-worker pool is grown FROM the 1-worker pool via launch(), so the row
    also exercises the scale-out path (column append, cost-table widening,
    machine-snapshot replacement) rather than a pre-sized pool.  Both rows
    carry the gated ``jax_csr`` prefix: the pool seam sitting between the
    router and its workers must not make ticks materially slower as the
    pool grows."""
    classes = 4
    rng = np.random.default_rng(seed)
    pool = EnginePool([WorkerSpec("w0", engine=_NullEngine())])
    router = Router(pool, max_batch=8)
    for workers in (1, 4):
        while pool.size < workers:
            pool.launch(WorkerSpec(f"w{pool.size}", engine=_NullEngine()))
        for c in range(classes):
            wc = (1 << (3 + c), 8)
            for e in range(pool.size):
                router.costs.update(wc, e, float(rng.uniform(0.5e-3, 2e-3)))
        best = np.inf
        dispatches = 0
        for _ in range(15):
            _submit(router, classes, per_class, rng)
            t0 = time.perf_counter()
            ds = router.tick()
            best = min(best, time.perf_counter() - t0)
            dispatches = len(ds)
        n, src, dst, data, comp = router.last_dag
        # same identity gate as the main rows: the pool-backed router's plan
        # must equal the dense padded sweep on the same DAG
        ref = ceft_jax(_graph(n, src, dst, data), comp, router.machine)
        res = router.last_plan
        assert np.array_equal(res.ceft, ref.ceft) and res.path == ref.path, \
            "pool-backed router plan diverged from the dense padded sweep"
        csv.row("serve_router", f"scaleout{workers}w", n, workers, len(src),
                "jax_csr_pool_scaleout", f"{best * 1e3:.3f}",
                f"{1.0 / best:.1f}", dispatches)
        if json_rows is not None:
            json_rows.append({
                "bench": "serve_router", "graph": f"scaleout{workers}w",
                "impl": "jax_csr_pool_scaleout", "n": int(n), "P": int(workers),
                "e": int(len(src)), "ms": float(best * 1e3), "speedup": None,
                "speedup_vs_padded": None,
            })


def _run_hedge(csv: CSV, seed: int, per_class: int,
               json_rows: list | None) -> None:
    """ISSUE 8: steady-tick latency with the deadline watchdog armed vs
    disarmed.  The armed timed region includes everything serving pays per
    dispatch when armed — tick + planned_span pricing + arm/disarm on the
    watchdog — with the monitor thread sweeping concurrently (deadlines far
    enough out that nothing fires: this measures bookkeeping, not faults).
    Flatness-asserted so check_regression's 2x gate on the jax_csr prefix
    catches a monitor thread or arming path that starts costing real time."""
    from repro.serve.queue import next_seq

    P, classes, budget = 4, 4, 4
    ms = {}
    for armed in (False, True):
        rng = np.random.default_rng(seed)
        kw = (dict(deadline_factor=50.0, min_deadline=10.0, wd_poll=0.005)
              if armed else {})
        router = _make_router(P, classes, rng, **kw)
        router.tick_budget = budget
        wd = router.watchdog
        if wd is not None:
            wd.start()
        try:
            _submit(router, classes, per_class, rng)
            ds = router.tick()                # warm: the one real plan
            _refill(router, ds, rng)
            best = np.inf
            for _ in range(30):
                t0 = time.perf_counter()
                ds = router.tick()
                if wd is not None:
                    for d in ds:
                        seq = next_seq()
                        wd.arm(seq, d, planned_span=router.planned_span(d),
                               engine=d.engine,
                               on_critical_path=d.on_critical_path)
                        wd.disarm(seq)
                best = min(best, time.perf_counter() - t0)
                _refill(router, ds, rng)
        finally:
            if wd is not None:
                wd.stop()
        assert router.stats["overdue"] == 0, \
            "hedge bench misconfigured: deadlines fired during timing"
        label = "armed" if armed else "disarmed"
        n = per_class * classes
        ms[armed] = best
        csv.row("serve_router", label, n, P, 0, "jax_csr_router_hedge",
                f"{best * 1e3:.3f}", f"{1.0 / best:.1f}", len(ds))
        if json_rows is not None:
            json_rows.append({
                "bench": "serve_router", "graph": label, "impl":
                "jax_csr_router_hedge", "n": int(n), "P": int(P), "e": 0,
                "ms": float(best * 1e3), "speedup": None,
                "speedup_vs_padded": None,
            })
    # the watchdog must be ~free when quiet (same noise floor as the steady
    # flatness gate: 0.2ms absolute absorbs timer jitter at smoke scale)
    assert ms[True] <= 1.25 * ms[False] + 2e-4, (
        f"armed steady tick regressed: {ms[False] * 1e3:.3f}ms disarmed vs "
        f"{ms[True] * 1e3:.3f}ms armed")


def _slo_sojourn(seed: int, flood: int, ngold: int,
                 tiers: dict | None) -> tuple[float, int, int]:
    """Gold-tenant P99 submit-to-dispatch sojourn under a low-tier flood.

    One workload class only, so the admission queue's drain order IS the
    dispatch order; ``tick_budget`` bounds each tick, so a request's sojourn
    is (ticks it waits) x (real per-tick planning cost) — wall-clock, with
    the SLO plane's own per-tick cost (deadline stamping + propagation on
    the gold requests) inside the measured region."""
    rng = np.random.default_rng(seed)
    queue = None if tiers is None else AdmissionQueue(tiers=tiers)
    router = _make_router(2, 1, rng, queue=queue)
    router.tick_budget = 4
    t_sub: dict[int, float] = {}
    gold_rids: list[int] = []
    reqs: list[Request] = []
    for _ in range(flood):                 # the flood submits FIRST
        for t in range(8):
            prompt = rng.integers(2, 100, 8).astype(np.int32)
            reqs.append(Request(f"low{t}", prompt, 8))
    for _ in range(ngold):                 # gold arrives behind all of it
        prompt = rng.integers(2, 100, 8).astype(np.int32)
        r = Request("gold", prompt, 8)
        gold_rids.append(r.rid)
        reqs.append(r)
    for r in reqs:
        assert router.submit(r), "slo bench overflowed the admission queue"
        t_sub[r.rid] = time.perf_counter()
    t_disp: dict[int, float] = {}
    ticks = 0
    while len(router.queue) or router.resident:
        ds = router.tick()
        ticks += 1
        now = time.perf_counter()
        for d in ds:
            for r in d.requests:
                t_disp[r.rid] = now
        assert ticks <= 4 * len(reqs), "slo bench tick loop failed to drain"
    assert len(t_disp) == len(reqs)
    gold = np.array([t_disp[rid] - t_sub[rid] for rid in gold_rids])
    return float(np.quantile(gold, 0.99)), len(reqs), ticks


def _run_slo(csv: CSV, seed: int, per_class: int,
             json_rows: list | None) -> None:
    """ISSUE 9: what weighted tiers buy a high-SLO tenant under flood.

    Identity first: uniform tier weights must reproduce the untiered
    insertion-order round-robin drain pop for pop (across chunked drains,
    so WRR credit persistence is in the check).  Then the adversarial run:
    8 low tenants flood, gold (weight 8, SLO-carrying) submits last; gold's
    P99 sojourn must be better tiered than untiered."""
    rng = np.random.default_rng(seed)
    uni = AdmissionQueue(tiers={f"t{i}": TenantTier(f"t{i}", 1.0)
                                for i in range(4)})
    plain = AdmissionQueue()
    for t in rng.integers(0, 4, 64):
        prompt = np.arange(4, dtype=np.int32)
        uni.submit(Request(f"t{t}", prompt, 4))
        plain.submit(Request(f"t{t}", prompt, 4))
    got_u: list[str] = []
    got_p: list[str] = []
    while len(uni) or len(plain):
        got_u += [r.tenant for r in uni.drain(3)]
        got_p += [r.tenant for r in plain.drain(3)]
    assert got_u == got_p, \
        "uniform tier weights diverged from the untiered round-robin drain"

    flood = max(4, min(16, per_class))
    ngold = 8
    tiers = {f"low{t}": TenantTier(f"low{t}", 1.0) for t in range(8)}
    tiers["gold"] = TenantTier("gold", 8.0, slo=60.0)
    # warm the single-class DAG shape's compiled sweep OUTSIDE the timed
    # runs: the first G=1 plan pays jit compile, and whichever config ran
    # first would otherwise absorb ~all of it into its sojourn numbers
    _slo_sojourn(seed, 1, 1, None)
    p99 = {}
    for label, tr in (("slo_untiered", None), ("slo_tiered", tiers)):
        t, n, ticks = _slo_sojourn(seed, flood, ngold, tr)
        p99[label] = t
        csv.row("serve_router", label, n, 2, 0, "jax_csr_router_slo",
                f"{t * 1e3:.3f}", f"{ticks}", ngold)
        if json_rows is not None:
            json_rows.append({
                "bench": "serve_router", "graph": label, "impl":
                "jax_csr_router_slo", "n": int(n), "P": 2, "e": 0,
                "ms": float(t * 1e3), "speedup": None,
                "speedup_vs_padded": None,
            })
    # the tiers' whole point: the weighted drain pulls gold forward through
    # the flood (w=8 vs 8x w=1 -> every other slot instead of every ninth),
    # so gold's tail sojourn must improve (0.2ms floor absorbs timer noise)
    assert p99["slo_tiered"] <= p99["slo_untiered"] + 2e-4, (
        f"tiered gold P99 regressed: {p99['slo_tiered'] * 1e3:.3f}ms tiered "
        f"vs {p99['slo_untiered'] * 1e3:.3f}ms untiered")


def _graph(n, src, dst, data):
    from repro.core.ceft_jax import request_graph
    return request_graph(n, src, dst, data)


if __name__ == "__main__":
    run()
