"""Paper Table 3 + Figs 5-6: % of experiments where CEFT's CPL / CEFT-CPOP's
makespan is longer / equal / shorter than CPOP's, per workload family."""
from __future__ import annotations

import numpy as np

from .common import CSV, WORKLOADS, cat3, make_experiment, run_algos, scale


def run(n_experiments: int = 160, seed: int = 7):
    n_experiments = max(8, int(n_experiments * scale()))
    csv = CSV(["table", "workload", "quantity", "longer_pct", "equal_pct",
               "shorter_pct", "n_experiments"])
    rng = np.random.default_rng(seed)
    for kind in WORKLOADS:
        cpl_cat = np.zeros(3, int)
        mk_cat = np.zeros(3, int)
        for _ in range(n_experiments):
            wl, _ = make_experiment(kind, rng)
            r = run_algos(wl, algos=("ceft_cpop", "cpop"))
            cpl_cat[cat3(r["ceft_cpl"], r["cpop_cpl"])] += 1
            mk_cat[cat3(r["ceft_cpop"]["makespan"], r["cpop"]["makespan"])] += 1
        for qty, cats in (("CPL", cpl_cat), ("makespan", mk_cat)):
            pct = 100 * cats / cats.sum()
            csv.row("table3", kind, qty, f"{pct[0]:.2f}", f"{pct[1]:.2f}",
                    f"{pct[2]:.2f}", cats.sum())


if __name__ == "__main__":
    run()
