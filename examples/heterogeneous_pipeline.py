"""The paper's technique as a runtime feature: plan pipeline stages for the
assigned architectures across a heterogeneous TPU fleet with CEFT, then react
to a straggling slice by re-planning (CEFT-CPOP).

Run:  PYTHONPATH=src python examples/heterogeneous_pipeline.py
"""
import numpy as np

import repro.configs as C
from repro.configs.base import SHAPES
from repro.sched import StragglerMonitor, build_layer_dag, plan_pipeline

for arch in ("llama3-405b", "jamba-v0.1-52b", "mamba2-2.7b"):
    for cell in ("train_4k", "decode_32k"):
        plan = plan_pipeline(C.get(arch), SHAPES[cell])
        classes = {}
        for s in plan.stages:
            classes[s.device_class] = classes.get(s.device_class, 0) + 1
        print(f"{arch:16s} {cell:10s} CPL={plan.cpl*1e3:9.2f}ms "
              f"makespan={plan.makespan*1e3:9.2f}ms (cpop {plan.makespan_cpop*1e3:9.2f}, "
              f"heft {plan.makespan_heft*1e3:9.2f})  classes={classes}")

# --- straggler scenario: the flops-rich class degrades mid-run ------------
print("\nstraggler: v5e-96 slice degrades 3x during glm4-9b training")
cfg = C.get("glm4-9b")
g, comp, m, _ = build_layer_dag(cfg, SHAPES["train_4k"], n_micro=4)
mon = StragglerMonitor(m.P, threshold=1.3)
for step in range(1, 8):
    times = np.ones(m.P)
    if step >= 4:
        times[0] = 3.0
    sched, ev = mon.maybe_replan(step, g, comp, m, times)
    if ev:
        print(f"  step {step}: class {ev.device_class} slowdown {ev.slowdown:.2f}x "
              f"-> replanned, makespan {ev.old_makespan*1e3:.1f} -> "
              f"{ev.new_makespan*1e3:.1f} ms (degraded costs)")
        used = sorted(set(m.inst_class[sched.proc].tolist()))
        print(f"  classes now in use: {used}")
        break
