"""Serve a small model with batched requests: prefill once, decode greedily
with per-sequence EOS, including an SWA (ring-buffer KV cache) variant.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import numpy as np

import repro.configs as C
from repro.configs.base import ArchConfig
from repro.serve import Engine, ServeConfig

# small dense model (trained weights would come from checkpoint.restore)
CFG = ArchConfig(
    name="demo-serve", family="dense",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
    vocab=4096, head_dim=32, remat="none",
)


def main():
    rng = np.random.default_rng(0)
    eng = Engine(CFG)
    prompts = rng.integers(2, CFG.vocab, (8, 16)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts, ServeConfig(max_new_tokens=24, eos_id=1))
    dt = time.time() - t0
    new = out.shape[1] - prompts.shape[1]
    print(f"batched decode: {out.shape[0]} seqs x {new} new tokens "
          f"in {dt:.2f}s ({out.shape[0] * new / dt:.0f} tok/s incl. compile)")
    print("sample:", out[0, :24].tolist())

    # sliding-window variant (mixtral-style ring cache, window < prompt)
    swa = dataclasses.replace(C.get("mixtral-8x22b", smoke=True), window=8)
    eng2 = Engine(swa)
    out2 = eng2.generate(prompts[:2, :12], ServeConfig(max_new_tokens=8, eos_id=1))
    print("SWA ring-cache decode ok:", out2.shape)

    # SSM (mamba2) O(1)-state variant
    eng3 = Engine(C.get("mamba2-2.7b", smoke=True))
    out3 = eng3.generate(prompts[:2, :12] % 256, ServeConfig(max_new_tokens=8, eos_id=1))
    print("SSM state decode ok:", out3.shape)


if __name__ == "__main__":
    main()
