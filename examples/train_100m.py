"""End-to-end driver: train a ~100M-parameter GQA decoder for a few hundred
steps on the local device mesh, with checkpointing, WSD schedule, straggler
monitoring, and a simulated mid-run node failure + recovery.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse

from repro.configs.base import ArchConfig, ShapeCell
from repro.launch.mesh import make_test_mesh
from repro.train import Trainer, TrainerConfig

# ~100M params: 12L x 768d (GPT-2-small-ish, llama-style blocks)
CFG_100M = ArchConfig(
    name="demo-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=2048,
    vocab=32768, head_dim=64, schedule="wsd", remat="none", loss_chunk=128,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="simulate a node failure at this step")
    ap.add_argument("--smoke", action="store_true",
                    help="~2M-param model: same code path, finishes in "
                         "seconds on a 1-core CPU box")
    args = ap.parse_args()

    cfg = CFG_100M
    if args.smoke:
        import dataclasses
        cfg = dataclasses.replace(cfg, name="demo-smoke", n_layers=2,
                                  d_model=256, n_heads=4, n_kv_heads=4,
                                  d_ff=512, vocab=2048)
        args.steps = min(args.steps, 20)
    # explicit --seq/--batch always win; otherwise scale-appropriate defaults
    args.seq = args.seq or (64 if args.smoke else 256)
    args.batch = args.batch or (4 if args.smoke else 8)

    cell = ShapeCell("train_demo", seq_len=args.seq, global_batch=args.batch,
                     kind="train")
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt,
        log_every=10, peak_lr=3e-4,
        fail_at_steps=(args.fail_at,) if args.fail_at else (),
    )
    n = cfg.n_params()
    print(f"model: {n/1e6:.1f}M params, {args.steps} steps, "
          f"{args.batch}x{args.seq} tokens/step")
    tr = Trainer(cfg, cell, tcfg, make_test_mesh)
    metrics = tr.run()
    losses = [m for m in metrics if "loss" in m]
    events = [m for m in metrics if "event" in m]
    print(f"\nstep {losses[0]['step']:4d}  loss {losses[0]['loss']:.4f}")
    print(f"step {losses[-1]['step']:4d}  loss {losses[-1]['loss']:.4f}")
    for e in events:
        print("event:", e)
    assert losses[-1]["loss"] < losses[0]["loss"], "loss did not improve"
    print("OK: loss improved; checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
