"""Quickstart: the paper's algorithm in five minutes.

1. Build a heterogeneous workload (the paper's RGG-high generator).
2. Find the true critical path with CEFT -- length AND partial assignment.
3. Compare against CPOP's estimate; schedule with CEFT-CPOP / CPOP / HEFT.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    ceft, ceft_cpop, cpop, heft, slack, slr, speedup, validate_schedule,
)
from repro.core.cpop import cpop_cpl
from repro.graphs import rgg

rng = np.random.default_rng(0)

# a 256-task application DAG on 8 heterogeneous processors, strongly
# heterogeneous execution times (the paper's RGG-high cost model)
wl = rgg("high", n=256, P=8, rng=rng, o=4, c=0.1, alpha=0.75, beta=50)
g, comp, machine = wl.graph, wl.comp, wl.machine

# --- the paper's contribution: the critical path and its partial schedule ---
res = ceft(g, comp, machine)
print(f"CEFT critical-path length : {res.cpl:10.1f}")
print(f"CPOP's realized CP length : {cpop_cpl(g, comp, machine):10.1f}")
print(f"CP tasks -> classes       : {res.path[:6]} ...")

# --- extend to full schedules (paper §6) ---
for name, algo in [("CEFT-CPOP", lambda: ceft_cpop(g, comp, machine, res)),
                   ("CPOP", lambda: cpop(g, comp, machine)),
                   ("HEFT", lambda: heft(g, comp, machine))]:
    s = algo()
    validate_schedule(s, g, comp, machine)
    print(f"{name:10s} makespan={s.makespan:10.1f}  speedup={speedup(s, comp, machine):5.2f}  "
          f"SLR={slr(s, g, comp):5.2f}  slack={slack(s, g, comp, machine):8.1f}")
