#!/usr/bin/env bash
# Tier-1 CI: optional dev deps, the test suite, and the substrate choke-point
# invariant (no raw version-sensitive mesh APIs outside src/repro/substrate/).
set -euo pipefail
cd "$(dirname "$0")/.."

# optional dev deps -- the suite must also pass without them (property tests
# auto-skip via tests/_hyp.py), so a failed install is not an error
if command -v pip >/dev/null 2>&1; then
    pip install --quiet hypothesis 2>/dev/null \
        || echo "ci: hypothesis unavailable, property tests will skip"
fi

echo "ci: forbidden-API grep (version-sensitive mesh calls outside substrate)"
# bare names too, so `from jax import set_mesh` can't sneak past; shard_map
# is matched only as a jax import/attribute since `from ..substrate import
# shard_map` is the sanctioned spelling
violations=$(grep -rnE "set_mesh|use_mesh|AxisType|get_abstract_mesh|jax\.shard_map|from jax import .*shard_map|jax\.experimental.*shard_map" \
    src/ --include='*.py' | grep -v "^src/repro/substrate/" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- raw mesh API outside src/repro/substrate/:"
    echo "$violations"
    exit 1
fi
echo "ci: choke-point invariant holds"

# Scoped sharding profiles (ISSUE 2): LOGICAL_RULES is the baseline table
# inside models/common.py only -- every other module resolves rules through
# the active ShardingProfile (sharding_profile context manager / explicit
# profile= arg), so concurrent engines can't race on a global dict.
# Validated against jax 0.4.37; the grep itself is version-independent and
# applies to the whole supported range (0.4.x and the 0.6+ mesh API).
echo "ci: forbidden-API grep (LOGICAL_RULES outside models/common.py)"
violations=$(grep -rn "LOGICAL_RULES" src/ tests/ --include='*.py' \
    | grep -v "^src/repro/models/common.py:" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- LOGICAL_RULES accessed outside src/repro/models/common.py:"
    echo "$violations"
    exit 1
fi
echo "ci: profile choke-point invariant holds"

# Level tables (ISSUE 3): the padded dense tables and the CSR level segments
# are built only by core/taskgraph.py (padded_level_tables /
# csr_level_segments).  No other module may reconstruct them by iterating
# TaskGraph.levels() -- everything downstream consumes the taskgraph builders,
# so the bucketing policy and tie-break ordering have a single owner.
echo "ci: forbidden-API grep (level-table construction outside core/taskgraph.py)"
violations=$(grep -rnE "\.levels\(\)|def padded_level_tables|def csr_level_segments" \
    src/ benchmarks/ --include='*.py' | grep -v "^src/repro/core/taskgraph.py:" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- level tables constructed outside src/repro/core/taskgraph.py:"
    echo "$violations"
    exit 1
fi
echo "ci: level-table choke-point invariant holds"

echo "ci: tier-1 tests"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

# Perf trajectory (ISSUE 3): refresh the machine-readable CEFT baseline on
# every CI pass so perf PRs have a trajectory file to diff against.  The
# shrunk scale keeps this a smoke-sized run; jax_csr rows are checked against
# jax_padded (bit-identical) and the float64 numpy path inside the bench.
echo "ci: CEFT perf baseline (BENCH_ceft.json, shrunk scale)"
REPRO_BENCH_SCALE=0.05 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only ceft_throughput --json BENCH_ceft.json \
    > /dev/null
echo "ci: wrote BENCH_ceft.json"
