#!/usr/bin/env bash
# Tier-1 CI: optional dev deps, the test suite, and the substrate choke-point
# invariant (no raw version-sensitive mesh APIs outside src/repro/substrate/).
set -euo pipefail
cd "$(dirname "$0")/.."

# jax version pin (ISSUE 4): the substrate + CEFT sweeps are validated on the
# 0.4.x line and the 0.6+ mesh API; anything else (0.5.x, pre-0.4) fails fast
# here instead of surfacing as cryptic trace errors mid-suite.  The producing
# version is also recorded into BENCH_ceft.json metadata by benchmarks/run.py.
echo "ci: jax version gate (supported window: 0.4.x / 0.6+)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import re
import sys

import jax

v = jax.__version__
m = re.match(r"(\d+)\.(\d+)", v)
mm = (int(m.group(1)), int(m.group(2))) if m else None
if mm is None or not (mm == (0, 4) or mm >= (0, 6)):
    sys.exit(f"ci: FAIL -- jax {v} is outside the supported 0.4.x / 0.6+ "
             "window (0.5.x changed mesh/shard_map semantics mid-flight and "
             "is not validated; upgrade to 0.6+ or pin 0.4.x)")
print(f"ci: jax {v} is inside the supported window")
PY

# optional dev deps -- the suite must also pass without them (property tests
# auto-skip via tests/_hyp.py), so a failed install is not an error
if command -v pip >/dev/null 2>&1; then
    pip install --quiet hypothesis 2>/dev/null \
        || echo "ci: hypothesis unavailable, property tests will skip"
fi

echo "ci: forbidden-API grep (version-sensitive mesh calls outside substrate)"
# bare names too, so `from jax import set_mesh` can't sneak past; shard_map
# is matched only as a jax import/attribute since `from ..substrate import
# shard_map` is the sanctioned spelling
violations=$(grep -rnE "set_mesh|use_mesh|AxisType|get_abstract_mesh|jax\.shard_map|from jax import .*shard_map|jax\.experimental.*shard_map" \
    src/ --include='*.py' | grep -v "^src/repro/substrate/" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- raw mesh API outside src/repro/substrate/:"
    echo "$violations"
    exit 1
fi
echo "ci: choke-point invariant holds"

# Scoped sharding profiles (ISSUE 2): LOGICAL_RULES is the baseline table
# inside models/common.py only -- every other module resolves rules through
# the active ShardingProfile (sharding_profile context manager / explicit
# profile= arg), so concurrent engines can't race on a global dict.
# Validated against jax 0.4.37; the grep itself is version-independent and
# applies to the whole supported range (0.4.x and the 0.6+ mesh API).
echo "ci: forbidden-API grep (LOGICAL_RULES outside models/common.py)"
violations=$(grep -rn "LOGICAL_RULES" src/ tests/ --include='*.py' \
    | grep -v "^src/repro/models/common.py:" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- LOGICAL_RULES accessed outside src/repro/models/common.py:"
    echo "$violations"
    exit 1
fi
echo "ci: profile choke-point invariant holds"

# Profile registry (ISSUE 5): CLI --profile choices derive from the PROFILES
# registry via models/common.py profile_names().  No launcher (or anything
# else in src/) may re-list the profile names in a hardcoded choices list --
# the lists drift the moment a profile is added.
echo "ci: forbidden-API grep (hardcoded profile-name choices lists)"
violations=$(grep -rnE 'choices=\[[^]]*"(baseline|opt1|serve|moe_ep)"' \
    src/ --include='*.py' | grep -v "^src/repro/models/common.py:" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- hardcoded profile-name list (use models.common.profile_names()):"
    echo "$violations"
    exit 1
fi
echo "ci: profile-registry invariant holds"

# Level tables (ISSUE 3): the padded dense tables and the CSR level segments
# are built only by core/taskgraph.py (padded_level_tables /
# csr_level_segments).  No other module may reconstruct them by iterating
# TaskGraph.levels() -- everything downstream consumes the taskgraph builders,
# so the bucketing policy and tie-break ordering have a single owner.
echo "ci: forbidden-API grep (level-table construction outside core/taskgraph.py)"
violations=$(grep -rnE "\.levels\(\)|def padded_level_tables|def csr_level_segments" \
    src/ benchmarks/ --include='*.py' | grep -v "^src/repro/core/taskgraph.py:" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- level tables constructed outside src/repro/core/taskgraph.py:"
    echo "$violations"
    exit 1
fi
echo "ci: level-table choke-point invariant holds"

# Bucketing policy (ISSUE 4): the jit-shape buckets (_geo_bucket), the
# fusion + hybrid-layout thresholds (CSR_FUSE_WASTE / CSR_DENSE_SKEW) and
# the CSR_TRACES counters are owned by core/ceft_jax.py alone, matching the
# level-table gate above -- everything else consumes csr_device_inputs /
# fuse_levels outputs, so changing the bucket policy (and hence what
# recompiles) has a single owner.
echo "ci: forbidden-API grep (CSR bucket policy outside core/ceft_jax.py)"
violations=$(grep -rnE "CSR_TRACES|CSR_FUSE|CSR_DENSE|_bucket\(|def _geo_bucket" \
    src/ benchmarks/ --include='*.py' | grep -v "^src/repro/core/ceft_jax.py:" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- CSR bucket policy accessed outside src/repro/core/ceft_jax.py:"
    echo "$violations"
    exit 1
fi
echo "ci: bucket-policy choke-point invariant holds"

# Plan-cache ownership (ISSUE 6): the graph store, the device-state store
# and the old ceft_jax one-slot caches (_GRAPH_STATE / _REQUEST_GRAPH) are
# owned by sched/plancache.py alone.  Nothing else in src/ or benchmarks/
# may hold segment-table or built-graph caching state -- the invalidation
# invariant (a cost delta may only skip work, never change the schedule)
# is only auditable while the cached state has a single owner.
echo "ci: forbidden-API grep (plan/graph caching state outside sched/plancache.py)"
violations=$(grep -rnE "_GRAPH_STATE|_REQUEST_GRAPH|_GRAPH_STORE|_DEVICE_STATE" \
    src/ benchmarks/ --include='*.py' | grep -v "^src/repro/sched/plancache.py:" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- plan/graph caching state outside src/repro/sched/plancache.py:"
    echo "$violations"
    exit 1
fi
echo "ci: plan-cache ownership invariant holds"

# Placement-plane ownership (ISSUE 7): worker lifecycle state -- the
# _WorkerState machine, the subprocess transport/bootstrap, and the pool
# member list -- is private to serve/pool.py.  The Router (and everything
# else) sees only the public pool API (launch/drain/mark_lost/generate/
# machine), so "where computation lives" keeps a single owner and the
# failure-as-degradation invariant stays auditable.
echo "ci: forbidden-API grep (worker lifecycle state outside serve/pool.py)"
violations=$(grep -rnE "_WorkerState|_worker_main|_SubprocWorker|_InprocWorker|_PoolMember|pool\._members" \
    src/ benchmarks/ --include='*.py' | grep -v "^src/repro/serve/pool.py:" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- worker lifecycle state accessed outside src/repro/serve/pool.py:"
    echo "$violations"
    exit 1
fi
echo "ci: placement-plane ownership invariant holds"

# Fault-injection containment (ISSUE 8): the chaos harness attaches through
# the pool's public handle-wrapper seam, and that seam (plus the injector
# machinery) must stay private to serve/faults.py -- production modules may
# not install handle middleware or reach fault hooks directly.  The launcher
# is the one sanctioned consumer (install_chaos behind --chaos-seed).
echo "ci: forbidden-API grep (fault-injection hooks outside serve/faults.py)"
violations=$(grep -rnE "add_handle_wrapper|_handle_wrappers|_FaultyHandle" \
    src/ benchmarks/ --include='*.py' \
    | grep -v "^src/repro/serve/pool.py:" \
    | grep -v "^src/repro/serve/faults.py:" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- fault-injection hook used outside src/repro/serve/faults.py:"
    echo "$violations"
    exit 1
fi
violations=$(grep -rnE "FaultInjector|FaultPlan|install_chaos" \
    src/ benchmarks/ --include='*.py' \
    | grep -v "^src/repro/serve/faults.py:" \
    | grep -v "^src/repro/launch/serve.py:" || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- fault machinery referenced outside faults.py/launch/serve.py:"
    echo "$violations"
    exit 1
fi
echo "ci: fault-injection containment invariant holds"

# Planner registry (ISSUE 10): serve/ and sched/ select planners by NAME
# through core/planners.py -- importing the scheduler functions themselves
# (ceft_cpop/cpop/heft/heft_down/ceft_heft_up/ceft_heft_down/bruteforce or
# raw list_schedule) would bypass the registry and fork the planner surface.
# Importing the planners module, CeftResult/Plan types, and the machinery
# modules (ceft_jax, machine, taskgraph) stays sanctioned.
echo "ci: forbidden-API grep (scheduler functions imported outside the planner registry)"
violations=$(grep -rnE "from \.\.core\.(cpop|heft|bruteforce) import|from \.\.core import [^#]*\b(ceft_cpop|cpop|heft|heft_down|ceft_heft_up|ceft_heft_down|bruteforce_cpl|list_schedule)\b" \
    src/repro/serve/ src/repro/sched/ --include='*.py' || true)
if [ -n "$violations" ]; then
    echo "ci: FAIL -- scheduler imported directly in serve/ or sched/ (use core.planners by name):"
    echo "$violations"
    exit 1
fi
echo "ci: planner-registry invariant holds"

# Docs completeness (ISSUE 9): docs/architecture.md's module map must name
# every module under src/repro/serve/ and src/repro/sched/ -- a new module
# lands with its line in the map or CI fails -- and every relative markdown
# link in docs/*.md and README.md must resolve to a real file, so the docs
# cannot silently rot as the tree moves.
echo "ci: docs check (module map complete, relative links resolve)"
python - <<'PY'
import pathlib
import re
import sys

root = pathlib.Path(".")
errors = []

arch = (root / "docs" / "architecture.md").read_text()
for pkg in ("serve", "sched"):
    for mod in sorted((root / "src" / "repro" / pkg).glob("*.py")):
        if mod.name == "__init__.py":
            continue
        if f"{pkg}/{mod.name}" not in arch:
            errors.append(f"docs/architecture.md: module map is missing "
                          f"{pkg}/{mod.name}")

link = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)]*)?\)")
for md in [root / "README.md", *sorted((root / "docs").glob("*.md"))]:
    for target, _frag in link.findall(md.read_text()):
        if "://" in target:
            continue
        if not (md.parent / target).exists():
            errors.append(f"{md}: broken relative link -> {target}")

if errors:
    print("ci: FAIL -- docs check:")
    for e in errors:
        print(f"  {e}")
    sys.exit(1)
print("ci: docs are complete and links resolve")
PY

echo "ci: tier-1 tests"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

# Router smoke (ISSUE 5): the CEFT-routed multi-tenant front-end end-to-end
# on real smoke engines -- two tenants, a two-profile pool, tiny decode.
echo "ci: router smoke (repro.launch.serve --router)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.serve \
    --router --tenants 2 --pool serve,baseline --requests 2 \
    --prompt-len 8 --max-new 2 > /dev/null
echo "ci: router smoke ok"

# Planner-registry smoke (ISSUE 10): the same front-end end-to-end with a
# NON-CEFT planner selected by name and the moldable fork-join axis on --
# the registry seam must serve real requests, not just pass unit tests.
echo "ci: non-CEFT planner smoke (--planner heft --max-split 2)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.serve \
    --router --tenants 2 --pool serve,baseline --requests 2 \
    --prompt-len 8 --max-new 2 --planner heft --max-split 2 \
    | grep "planner=heft" > /dev/null
echo "ci: non-CEFT planner smoke ok"

# Chaos smoke (ISSUE 8): the same front-end under the seeded fault injector
# (kills + hangs + delayed/duplicated replies scheduled by the seed) with
# the deadline watchdog armed.  The launcher exits nonzero unless every
# admitted request completed exactly once and hedge work stayed bounded by
# the overdue critical-path count -- the chaos soak's acceptance, as a smoke.
echo "ci: chaos smoke (repro.launch.serve --router --chaos-seed)"
# seed 13 @ rate 0.35 schedules a kill, two hangs and a held-duplicate reply
# across the first calls -- verified deterministic by FaultPlan.seeded
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.serve \
    --router --tenants 2 --pool serve,baseline --pool-size 4 --requests 3 \
    --prompt-len 8 --max-new 2 --deadline-factor 3 --chaos-seed 13 \
    --chaos-rate 0.35 \
    | grep "chaos: every admitted request completed exactly once"
echo "ci: chaos smoke ok"

# Perf trajectory + regression gate (ISSUE 3 + 4): refresh the
# machine-readable CEFT baseline on every CI pass, then diff the fresh rows
# against the *committed* baseline -- a >2x slowdown of any jax_csr row fails
# CI (tolerant of smoke-scale noise via the absolute-ms floor; rows absent
# from the baseline are skipped).  The committed baseline is assumed to come
# from comparable hardware (each passing CI run rewrites it, so committing
# the refreshed file keeps the baseline anchored to the CI machine); on a
# much slower box, regenerate the baseline once before trusting the gate.
# The shrunk scale keeps this a smoke-sized run; jax_csr rows are checked
# against jax_padded (bit-identical) and the float64 numpy path inside the
# bench.
echo "ci: CEFT perf baseline (BENCH_ceft.json, shrunk scale)"
baseline=$(mktemp)
trap 'rm -f "$baseline"' EXIT
if ! git show HEAD:BENCH_ceft.json > "$baseline" 2>/dev/null; then
    cp BENCH_ceft.json "$baseline"   # no git history: gate against last run
fi
# the tournament suite rides in the same pass: its in-bench asserts (the
# loud NONZERO misidentification rate, the oracle dominance check, and the
# moldable router's mapping-change check) make it a correctness gate too
REPRO_BENCH_SCALE=0.05 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only ceft_throughput serve_router tournament \
    --json BENCH_ceft.json > /dev/null
echo "ci: wrote BENCH_ceft.json"
echo "ci: perf-regression gate (fresh jax_csr rows vs committed baseline)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.check_regression "$baseline" BENCH_ceft.json \
    --impl jax_csr --threshold 2.0
