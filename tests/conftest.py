import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import settings
except ModuleNotFoundError:  # property tests auto-skip via tests/_hyp.py
    settings = None

REPO = Path(__file__).resolve().parent.parent


def run_isolated_script(body: str, *, fake_devices: int | None = None,
                        env: dict | None = None, timeout: int = 500,
                        marker: str | None = None):
    """Run ``body`` in a fresh interpreter with ``src/`` on PYTHONPATH.

    The shared bootstrap for every test that needs its own process — e.g.
    because the fake host-device count must be set before jax initializes
    (``fake_devices`` prepends the XLA_FLAGS override; the calling test
    process keeps its single real CPU device), or because it exercises the
    engine pool's subprocess workers end-to-end.  Asserts exit code 0 (and
    that ``marker`` appeared on stdout, when given); returns the completed
    process for further assertions.
    """
    prelude = ""
    if fake_devices is not None:
        prelude = (
            "import os\n"
            "os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={int(fake_devices)}'\n")
    full_env = dict(os.environ)
    pp = full_env.get("PYTHONPATH", "")
    full_env["PYTHONPATH"] = str(REPO / "src") + (os.pathsep + pp if pp else "")
    full_env.update(env or {})
    r = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(body)],
        env=full_env, capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    if marker is not None:
        assert marker in r.stdout, r.stdout + r.stderr
    return r

if settings is not None:
    # keep hypothesis fast on the 1-core CI box
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_random_dag(n, p_edge, rng, data_range=(0.5, 5.0)):
    """Random DAG over topologically-ordered ids; every non-root vertex gets
    at least one parent so level-0 is the only source frontier."""
    from repro.core import from_edges

    edges = []
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < p_edge:
                edges.append((i, j, float(rng.uniform(*data_range))))
    have_parent = {d for _, d, _ in edges}
    for j in range(1, n):
        if j not in have_parent:
            i = int(rng.integers(0, j))
            edges.append((i, j, float(rng.uniform(*data_range))))
    return from_edges(n, edges)
