import numpy as np
import pytest

try:
    from hypothesis import settings
except ModuleNotFoundError:  # property tests auto-skip via tests/_hyp.py
    settings = None

if settings is not None:
    # keep hypothesis fast on the 1-core CI box
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_random_dag(n, p_edge, rng, data_range=(0.5, 5.0)):
    """Random DAG over topologically-ordered ids; every non-root vertex gets
    at least one parent so level-0 is the only source frontier."""
    from repro.core import from_edges

    edges = []
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < p_edge:
                edges.append((i, j, float(rng.uniform(*data_range))))
    have_parent = {d for _, d, _ in edges}
    for j in range(1, n):
        if j not in have_parent:
            i = int(rng.integers(0, j))
            edges.append((i, j, float(rng.uniform(*data_range))))
    return from_edges(n, edges)
