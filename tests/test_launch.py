"""Launch-layer units: HLO collective parser, sharding resolution with
divisibility degradation + profiles, input specs for every cell."""
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.configs.base import SHAPES, cells_for
from repro.launch.hlo_stats import collective_stats, _shape_bytes
from repro.models.common import (
    active_profile,
    resolve_spec,
    sharding_profile,
)
from repro.models.model import build

HLO = """\
HloModule jit_f

%body.10 (arg: (s32[], f32[128,64])) -> (s32[], f32[128,64]) {
  %ag.1 = f32[128,64]{1,0} all-gather(f32[8,64]{1,0} %p), replica_groups={}, dimensions={0}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%sum.5
}

%cond.11 (arg: (s32[], f32[128,64])) -> pred[] {
  %c = s32[] constant(7)
  %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main.42 (p0: f32[8,64]) -> f32[128,64] {
  %w = (s32[], f32[128,64]) while((s32[], f32[128,64]) %t), condition=%cond.11, body=%body.10
  %ag.2 = bf16[256]{0} all-gather(bf16[16]{0} %q), dimensions={0}
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,64]{1,0} all-gather(...)") == 128 * 64 * 4
    assert _shape_bytes("bf16[256]{0}") == 512
    assert _shape_bytes("pred[] compare") == 0 or _shape_bytes("pred[]") >= 0


def test_collective_stats_weights_while_loops():
    st = collective_stats(HLO, n_devices=4)
    # body collectives x trip count 7 (+ all-reduce factor 2) + entry all-gather
    expect = 7 * (128 * 64 * 4 + 2 * 128 * 4) + 256 * 2
    assert st["collective_bytes_per_device"] == pytest.approx(expect)
    assert st["op_counts"]["all-gather"] == 2
    assert st["op_counts"]["all-reduce"] == 1
    # flat (structural) sum counts the body once
    flat = (128 * 64 * 4 + 2 * 128 * 4 + 256 * 2) * 4
    assert st["collective_bytes_flat"] == pytest.approx(flat)


def test_resolve_spec_degradation():
    ms = {"data": 16, "model": 16}
    # divisible: shards; non-divisible: drops
    s = resolve_spec((128, 4096), ("heads", "ffn"), ms)
    assert s[0] == "model" or s[1] == "model"
    s2 = resolve_spec((36, 64), ("heads", "none"), ms)
    assert s2[0] is None  # 36 % 16 != 0 -> replicated
    # no axis used twice
    s3 = resolve_spec((256, 256), ("heads", "ffn"), ms)
    used = [x for x in s3 if x is not None]
    assert len(set(used)) == len(used)


def test_profile_switching_roundtrip():
    with sharding_profile("serve") as prof:
        assert prof.rule("batch") == ()
        assert prof.rule("qkv") == ("model", "data")
        assert active_profile() is prof
    # exiting the block restores baseline resolution
    base = active_profile()
    assert base.rule("batch") == ("pod", "data")
    assert base.rule("qkv") == ("model",)


def test_resolve_spec_takes_explicit_profile():
    ms = {"data": 16, "model": 16}
    s_base = resolve_spec((256, 4096), ("batch", "qkv"), ms, profile="baseline")
    s_serve = resolve_spec((256, 4096), ("batch", "qkv"), ms, profile="serve")
    assert s_base[0] is not None      # batch shards under baseline
    assert s_serve[0] is None         # serve replicates decode activations
    assert s_serve[1] == ("model", "data")


@pytest.mark.parametrize("arch", C.ARCHS)
def test_input_specs_cover_all_cells(arch):
    cfg = C.get(arch)
    model = build(cfg)
    for cell_name in cells_for(cfg):
        cell = SHAPES[cell_name]
        specs = model.input_specs(cell)
        assert specs, (arch, cell_name)
        for k, v in specs.items():
            assert all(d > 0 for d in v.shape), (arch, cell_name, k)
        if cell.kind == "train":
            assert "labels" in specs
        if cell.kind == "decode":
            assert specs["tokens"].shape[1] == 1
            assert "pos" in specs


def test_cells_for_skip_list():
    """long_500k only for sub-quadratic mixers (DESIGN.md skip list)."""
    runs_long = {a for a in C.ARCHS if "long_500k" in cells_for(C.get(a))}
    assert runs_long == {"jamba-v0.1-52b", "mixtral-8x22b", "mamba2-2.7b"}
