"""Substrate tests: the version-portable mesh/sharding compat layer (both
JAX API generations), optimizer, schedules, checkpointing, data determinism,
gradient compression."""
import contextlib
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro import checkpoint as ckpt
from repro import substrate
from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamW, warmup_cosine, wsd
from repro.optim.grad_compress import ef_quantize, ef_quantize_tree, init_ef


# ---------------------------------------------------- mesh/sharding compat
def test_legacy_generation_native():
    """On jax 0.4.x none of the modern attrs exist; the substrate must run
    entirely on Mesh.__enter__ + thread-local resources."""
    if hasattr(jax, "set_mesh") or hasattr(jax.sharding, "use_mesh"):
        pytest.skip("installed jax is modern; legacy path covered via fakes")
    assert substrate.jax_mesh_api() == "legacy"
    mesh = substrate.make_mesh((1, 1), ("data", "model"))
    assert substrate.mesh_axis_sizes(mesh) == {"data": 1, "model": 1}
    assert substrate.current_abstract_mesh() is None
    with substrate.mesh_context(mesh):
        assert substrate.current_axis_sizes() == {"data": 1, "model": 1}
    assert substrate.current_axis_sizes() is None


def test_make_mesh_insufficient_devices():
    with pytest.raises(RuntimeError, match="devices"):
        substrate.make_mesh((1024, 64), ("data", "model"))


def test_constrain_no_mesh_is_identity():
    x = jnp.ones((4, 4))
    assert substrate.constrain(x, "data", "model") is x
    assert substrate.constrain_spec(x, PartitionSpec("data", None)) is x
    from repro.models.common import constrain as logical_constrain
    assert logical_constrain(x, "batch", "embed_d") is x


def test_constrain_under_active_mesh_jit():
    mesh = substrate.make_mesh((1, 1), ("data", "model"))
    x = jnp.ones((4, 4))
    with substrate.mesh_context(mesh):
        y = jax.jit(lambda a: substrate.constrain(a, "data", None))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


class _FakeAbstractMesh:
    def __init__(self, sizes):
        self._sizes = dict(sizes)

    @property
    def empty(self):
        return not self._sizes

    @property
    def shape(self):
        return dict(self._sizes)

    @property
    def axis_names(self):
        return tuple(self._sizes)


def _install_modern_fakes(monkeypatch, calls, state):
    """Simulate the >=0.6 API generation on whatever jax is installed."""

    @contextlib.contextmanager
    def fake_set_mesh(mesh):
        calls.setdefault("set_mesh", []).append(mesh)
        prev = state["mesh"]
        state["mesh"] = mesh
        try:
            yield mesh
        finally:
            state["mesh"] = prev

    def fake_get_abstract_mesh():
        m = state["mesh"]
        return _FakeAbstractMesh({} if m is None else m.shape)

    def fake_make_mesh(shape, axes, *, devices=None, axis_types=None):
        calls["make_mesh"] = {"shape": tuple(shape), "axes": tuple(axes),
                              "axis_types": axis_types}
        return _FakeAbstractMesh(dict(zip(axes, shape)))

    def fake_wsc(x, spec):
        calls.setdefault("wsc", []).append(spec)
        return x

    fake_axis_type = types.SimpleNamespace(Auto="auto")
    monkeypatch.setattr(jax, "set_mesh", fake_set_mesh, raising=False)
    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh, raising=False)
    monkeypatch.setattr(jax.sharding, "AxisType", fake_axis_type, raising=False)
    monkeypatch.setattr(jax.sharding, "get_abstract_mesh",
                        fake_get_abstract_mesh, raising=False)
    monkeypatch.setattr(jax.lax, "with_sharding_constraint", fake_wsc)
    return fake_axis_type


def test_modern_generation_routing(monkeypatch):
    calls, state = {}, {"mesh": None}
    fake_axis_type = _install_modern_fakes(monkeypatch, calls, state)
    assert substrate.jax_mesh_api() == "modern"

    mesh = substrate.make_mesh((1, 1), ("data", "model"))
    assert calls["make_mesh"]["axis_types"] == (fake_axis_type.Auto,) * 2
    assert substrate.mesh_axis_sizes is not None  # unchanged helper

    assert substrate.current_abstract_mesh() is None  # empty abstract mesh
    with substrate.mesh_context(mesh):
        assert calls["set_mesh"] == [mesh]
        assert substrate.current_axis_sizes() == {"data": 1, "model": 1}
    assert substrate.current_axis_sizes() is None


def test_modern_constrain_divisibility_degradation(monkeypatch):
    calls, state = {}, {"mesh": None}
    _install_modern_fakes(monkeypatch, calls, state)
    mesh = _FakeAbstractMesh({"data": 2, "model": 4})
    x = np.ones((4, 6), np.float32)
    with substrate.mesh_context(mesh):
        substrate.constrain(x, "data", "model")
    # dim0=4 divides data=2; dim1=6 does not divide model=4 -> dropped
    assert calls["wsc"] == [PartitionSpec("data", None)]


def test_shard_map_modern_kwarg_detection(monkeypatch):
    captured = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        captured.update(mesh=mesh, check_vma=check_vma)
        return f

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    out = substrate.shard_map(lambda a: a, mesh="m", in_specs=(), out_specs=())
    assert captured == {"mesh": "m", "check_vma": False}
    assert callable(out)


def test_shard_map_legacy_executes():
    mesh = substrate.make_mesh((1,), ("data",))
    f = substrate.shard_map(lambda a: a * 2, mesh=mesh,
                            in_specs=(PartitionSpec(),),
                            out_specs=PartitionSpec())
    np.testing.assert_array_equal(np.asarray(f(jnp.ones(4))), 2 * np.ones(4))


def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - jnp.asarray([1.0, 2.0, 3.0])) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-3


def test_adamw_grad_clip_and_bias_correction():
    opt = AdamW(lr=1e-2, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    g = {"w": jnp.full(4, 100.0)}
    new_p, state, gn = opt.update(g, state, params)
    assert float(gn) == pytest.approx(200.0, rel=1e-5)  # ||g|| = sqrt(4*100^2)
    # first step of Adam moves by ~lr regardless of grad scale
    assert np.allclose(np.asarray(new_p["w"]), -1e-2, rtol=1e-3)


def test_schedules():
    cos = warmup_cosine(1.0, warmup=10, total=100)
    assert float(cos(jnp.asarray(0))) == 0.0
    assert float(cos(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)
    w = wsd(1.0, warmup=10, total=100, decay_frac=0.2)
    assert float(w(jnp.asarray(50))) == pytest.approx(1.0)   # stable phase
    assert float(w(jnp.asarray(80))) == pytest.approx(1.0)   # decay start
    assert float(w(jnp.asarray(100))) == pytest.approx(0.01, rel=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    ckpt.save(tmp_path, 7, tree)
    assert ckpt.latest_valid(tmp_path) == 7
    like = jax.tree.map(lambda x: np.zeros_like(x), tree)
    out = ckpt.restore(tmp_path, 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_fallback(tmp_path):
    tree = {"w": jnp.ones(8)}
    ckpt.save(tmp_path, 1, tree)
    ckpt.save(tmp_path, 2, jax.tree.map(lambda x: x * 2, tree))
    # corrupt the newest shard
    shard = tmp_path / "step_2" / "000000.npy"
    shard.write_bytes(b"garbage")
    assert ckpt.latest_valid(tmp_path) == 1  # falls back to the intact one
    out = ckpt.restore(tmp_path, 1, {"w": np.zeros(8)})
    np.testing.assert_array_equal(out["w"], np.ones(8))


def test_checkpoint_async(tmp_path):
    tree = {"w": jnp.full(16, 3.0)}
    t = ckpt.save(tmp_path, 5, tree, async_=True)
    t.join()
    assert ckpt.latest_valid(tmp_path) == 5


def test_data_determinism_and_restartability():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=4, seed=9)
    a = SyntheticLM(cfg)
    b = SyntheticLM(cfg)  # a "restarted" pipeline
    for step in (0, 5, 17):
        x, y = a.batch(step), b.batch(step)
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])
    # labels are tokens shifted by one
    x = a.batch(3)
    np.testing.assert_array_equal(x["tokens"][:, 1:], x["labels"][:, :-1])
    # structure: not uniform (zipf-ish marginal)
    counts = np.bincount(x["tokens"].ravel(), minlength=128)
    assert counts.max() > 4 * max(counts.mean(), 1)


def test_error_feedback_invariant():
    """g + ef == g_hat + new_ef exactly (per step), so the accumulated
    quantization error never grows."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(512,)) * 10, jnp.float32)
    ef = jnp.zeros(512)
    for _ in range(50):
        gh, ef2 = ef_quantize(g, ef)
        np.testing.assert_allclose(np.asarray(g + ef), np.asarray(gh + ef2),
                                   rtol=1e-5, atol=1e-4)
        ef = ef2
    # the error stays bounded by one quantization bucket
    assert float(jnp.abs(ef).max()) < float(jnp.abs(g).max()) / 127 * 2


def test_ef_tree_and_sgd_convergence_with_compression():
    """SGD with EF-int8 compressed grads converges to the same optimum."""
    target = jnp.asarray([1.0, -2.0, 0.5, 3.0])
    params = {"w": jnp.zeros(4)}
    ef = init_ef(params)
    lr = 0.05
    for _ in range(400):
        g = {"w": 2 * (params["w"] - target)}
        gh, ef = ef_quantize_tree(g, ef)
        params = {"w": params["w"] - lr * gh["w"]}
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)
