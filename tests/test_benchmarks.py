"""Benchmark-harness smoke: each suite runs end-to-end at tiny scale and
emits well-formed CSV (guards the reproduction tooling itself)."""
import io
import os
from contextlib import redirect_stdout

import pytest


@pytest.fixture(autouse=True)
def tiny_scale(monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_SCALE", "0.02")


def _capture(fn, *a, **k):
    buf = io.StringIO()
    with redirect_stdout(buf):
        fn(*a, **k)
    out = buf.getvalue().strip().splitlines()
    assert len(out) >= 2, out
    header = out[0].split(",")
    for line in out[1:]:
        assert len(line.split(",")) == len(header), line
    return out


def test_table3_csv():
    from benchmarks import table3
    out = _capture(table3.run, n_experiments=50)
    assert any("table3" in l for l in out[1:])


def test_sweeps_csv():
    from benchmarks import sweeps
    out = _capture(sweeps.run, n_rep=50)
    assert any("fig10" in l for l in out)


def test_realworld_csv():
    from benchmarks import realworld
    out = _capture(realworld.run, n_rep=50)
    assert any("fig15_18" in l for l in out)


# ------------------------------------------------- perf-regression gate (ISSUE 4)
def _traj(rows, scale=0.05):
    base = {"bench": "ceft_throughput", "graph": "rgg_high", "impl": "jax_csr",
            "n": 64, "P": 4, "e": 256}
    return {"schema": 1, "scale": scale,
            "rows": [{**base, **r} for r in rows]}


def test_check_regression_passes_on_equal_and_faster_rows():
    from benchmarks.check_regression import check
    baseline = _traj([{"ms": 2.0}, {"graph": "star", "ms": 5.0}])
    fresh = _traj([{"ms": 2.1}, {"graph": "star", "ms": 1.0}])
    assert check(baseline, fresh) == []


def test_check_regression_fails_on_2x_slowdown():
    from benchmarks.check_regression import check
    baseline = _traj([{"ms": 2.0}])
    fresh = _traj([{"ms": 6.5}])  # 3.25x and > abs floor
    failures = check(baseline, fresh)
    assert len(failures) == 1 and "3.2" in failures[0]


def test_check_regression_tolerates_smoke_scale_noise():
    """Sub-millisecond rows can blip >2x from scheduler noise alone: the
    absolute-ms floor keeps them from failing the gate."""
    from benchmarks.check_regression import check
    baseline = _traj([{"ms": 0.10}])
    fresh = _traj([{"ms": 0.35}])  # 3.5x but only +0.25ms
    assert check(baseline, fresh) == []


def test_check_regression_skips_rows_absent_from_baseline():
    from benchmarks.check_regression import check
    baseline = _traj([{"ms": 2.0}])
    fresh = _traj([{"ms": 2.0}, {"graph": "brand_new", "ms": 500.0}])
    assert check(baseline, fresh) == []


def test_check_regression_gates_the_impl_family_by_prefix():
    """--impl jax_csr must also gate the batched jax_csr_vmap8 row."""
    from benchmarks.check_regression import check
    baseline = _traj([{"ms": 2.0}, {"impl": "jax_csr_vmap8", "ms": 2.0}])
    fresh = _traj([{"ms": 2.0}, {"impl": "jax_csr_vmap8", "ms": 30.0}])
    failures = check(baseline, fresh)
    assert len(failures) == 1 and "15.0" in failures[0]
    # non-family rows (e.g. jax_padded) stay exempt
    baseline = _traj([{"ms": 2.0}, {"impl": "jax_padded", "ms": 2.0}])
    fresh = _traj([{"ms": 2.0}, {"impl": "jax_padded", "ms": 30.0}])
    assert check(baseline, fresh) == []


def test_check_regression_fails_when_gate_disarmed_or_scale_mismatch():
    from benchmarks.check_regression import check
    baseline = _traj([{"ms": 2.0}])
    # renamed graph: zero matched rows must fail, not silently pass
    fresh = _traj([{"graph": "renamed", "ms": 2.0}])
    assert any("disarmed" in f for f in check(baseline, fresh))
    # cross-scale timings are not comparable
    assert any("scale" in f for f in check(baseline, _traj([{"ms": 2.0}], scale=1.0)))


def test_check_regression_cli_roundtrip(tmp_path):
    import json
    from benchmarks.check_regression import main
    b, f = tmp_path / "base.json", tmp_path / "fresh.json"
    b.write_text(json.dumps(_traj([{"ms": 2.0}])))
    f.write_text(json.dumps(_traj([{"ms": 2.0}])))
    assert main([str(b), str(f)]) == 0
    f.write_text(json.dumps(_traj([{"ms": 30.0}])))
    assert main([str(b), str(f)]) == 1


def test_throughput_json_rows_cover_new_impls_and_deep_graphs():
    """The trajectory file must carry the fused-CSR story: batched-CSR rows
    and the deep narrow (chain / GE) rows the fusion targets."""
    from benchmarks import ceft_throughput
    rows: list = []
    _capture(ceft_throughput.run, json_rows=rows)
    benches = {r["bench"] for r in rows}
    assert "ceft_deep" in benches
    graphs = {r["graph"] for r in rows if r["bench"] == "ceft_deep"}
    assert {"chain", "realworld_GE"} <= graphs
    impls = {r["impl"] for r in rows}
    assert {"jax_vmap8", "jax_csr_vmap8"} <= impls


def test_serve_router_bench_emits_gated_rows():
    """The router bench's planning rows land in the trajectory file under a
    jax_csr-prefixed impl, so the committed check_regression gate (--impl
    jax_csr) covers serving-tier planning regressions too."""
    from benchmarks import serve_router
    from benchmarks.check_regression import check
    rows: list = []
    out = _capture(serve_router.run, json_rows=rows)
    assert any("serve_router" in l for l in out[1:])
    assert rows and all(r["bench"] == "serve_router" for r in rows)
    gated = [r for r in rows if r["impl"].startswith("jax_csr")]
    context = [r for r in rows if not r["impl"].startswith("jax_csr")]
    assert {"jax_csr_router", "jax_csr_router_steady"} <= {
        r["impl"] for r in gated}
    # steady-state rows at both resident scales (flatness asserted in-bench)
    assert {r["graph"] for r in rows
            if r["impl"] == "jax_csr_router_steady"} == {"res1x", "res8x"}
    # the classic-HEFT context row stays OUTSIDE the gate prefix but is
    # registry-checked: its planner name rides in the row metadata
    assert context and all(r["impl"] == "heft_router"
                           and r.get("planner") == "heft"
                           and "identity_checked" not in r
                           for r in context)
    traj = {"schema": 1, "scale": 0.02, "rows": rows}
    assert check(traj, traj) == []       # matched by the default gate impl


def test_summarize_roundtrip(tmp_path):
    from benchmarks import table3, summarize
    buf = io.StringIO()
    with redirect_stdout(buf):
        table3.run(n_experiments=50)
    p = tmp_path / "bench.csv"
    p.write_text(buf.getvalue())
    rows = summarize.load(str(p))
    md = summarize.table3(rows)
    assert "| classic | CPL |" in md
