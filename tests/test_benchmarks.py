"""Benchmark-harness smoke: each suite runs end-to-end at tiny scale and
emits well-formed CSV (guards the reproduction tooling itself)."""
import io
import os
from contextlib import redirect_stdout

import pytest


@pytest.fixture(autouse=True)
def tiny_scale(monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_SCALE", "0.02")


def _capture(fn, *a, **k):
    buf = io.StringIO()
    with redirect_stdout(buf):
        fn(*a, **k)
    out = buf.getvalue().strip().splitlines()
    assert len(out) >= 2, out
    header = out[0].split(",")
    for line in out[1:]:
        assert len(line.split(",")) == len(header), line
    return out


def test_table3_csv():
    from benchmarks import table3
    out = _capture(table3.run, n_experiments=50)
    assert any("table3" in l for l in out[1:])


def test_sweeps_csv():
    from benchmarks import sweeps
    out = _capture(sweeps.run, n_rep=50)
    assert any("fig10" in l for l in out)


def test_realworld_csv():
    from benchmarks import realworld
    out = _capture(realworld.run, n_rep=50)
    assert any("fig15_18" in l for l in out)


def test_summarize_roundtrip(tmp_path):
    from benchmarks import table3, summarize
    buf = io.StringIO()
    with redirect_stdout(buf):
        table3.run(n_experiments=50)
    p = tmp_path / "bench.csv"
    p.write_text(buf.getvalue())
    rows = summarize.load(str(p))
    md = summarize.table3(rows)
    assert "| classic | CPL |" in md
