"""CSR/edge-centric CEFT sweep (ISSUE 3): equivalence against the paper's
Algorithm 1 on adversarial shapes, bit-identity against the padded dense
sweep, tie-breaking, and the bounded-compilation (bucketed jit shapes)
guarantee."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    ceft,
    ceft_reference,
    csr_level_segments,
    from_edges,
    linear_chain,
    random_machine,
    uniform_machine,
)
from repro.core.ceft_jax import (
    CSR_TRACES,
    ceft_jax,
    ceft_jax_csr,
    csr_device_inputs,
)
from repro.graphs import (
    epigenomics,
    fft_graph,
    gaussian_elimination,
    heavy_tail_fan_in,
    molecular_dynamics,
    rgg,
    star_fan_in,
)
from conftest import make_random_dag


def _machine(P, seed=0):
    return random_machine(P, np.random.default_rng(seed),
                          bw_range=(0.5, 2.0), L_range=(0.0, 1.0))


def _assert_equiv(g, comp, m):
    """CSR sweep == Algorithm 1 (values, cpl, backtracked path) and
    bit-identical to the padded dense jax sweep (same f32 arithmetic)."""
    ref = ceft_reference(g, comp, m)
    pad = ceft_jax(g, comp, m)
    csr = ceft_jax_csr(g, comp, m)
    np.testing.assert_allclose(csr.ceft, ref.ceft, rtol=2e-5)
    assert csr.cpl == pytest.approx(ref.cpl, rel=2e-5)
    assert csr.path == ref.path
    np.testing.assert_array_equal(csr.ceft, pad.ceft)
    np.testing.assert_array_equal(csr.pred_task, pad.pred_task)
    np.testing.assert_array_equal(csr.pred_proc, pad.pred_proc)
    assert csr.path == pad.path and csr.cpl == pad.cpl


# ------------------------------------------------------------ adversarial shapes
def test_single_task():
    g = from_edges(1, [])
    comp = np.array([[3.0, 7.0]])
    _assert_equiv(g, comp, _machine(2))


def test_linear_chain():
    rng = np.random.default_rng(1)
    g = linear_chain(17, data=2.5)
    _assert_equiv(g, rng.uniform(1, 10, (17, 3)), _machine(3))


def test_star_fan_in_degree_much_larger_than_mean():
    rng = np.random.default_rng(2)
    g = star_fan_in(65)  # sink in-degree 64, every other in-degree 0
    assert int(g.in_degree.max()) == 64
    _assert_equiv(g, rng.uniform(1, 10, (65, 4)), _machine(4))


def test_heavy_tail_fan_in():
    rng = np.random.default_rng(3)
    g = heavy_tail_fan_in(80, rng)
    assert int(g.in_degree.max()) > 2 * float(g.in_degree.mean())
    _assert_equiv(g, rng.uniform(1, 10, (80, 3)), _machine(3))


@pytest.mark.parametrize("seed,g", [
    (101, gaussian_elimination(6)),
    (102, fft_graph(8)),
    (103, molecular_dynamics()),
    (104, epigenomics(6)),
])
def test_realworld_graphs(seed, g):
    rng = np.random.default_rng(seed)
    _assert_equiv(g, rng.uniform(1, 10, (g.n, 4)), _machine(4))


@pytest.mark.parametrize("seed,g", [
    (201, gaussian_elimination(6)),
    (202, molecular_dynamics()),
    (203, star_fan_in(33)),
])
def test_transposed_graphs(seed, g):
    """The edge-reversed graphs rank_ceft_up sweeps (paper §8.2)."""
    gt = g.transpose()
    rng = np.random.default_rng(seed)
    _assert_equiv(gt, rng.uniform(1, 10, (gt.n, 3)), _machine(3))


def test_tie_breaking_matches_reference():
    """Exactly-tied candidates (integer weights, homogeneous machine): the
    first maximal parent in ascending-id order must win, as in Algorithm 1."""
    # two parents of 3 with identical values and identical edges, twice over
    g = from_edges(4, [(0, 3, 1.0), (1, 3, 1.0), (2, 3, 1.0)])
    comp = np.array([[2.0, 2.0], [2.0, 2.0], [2.0, 2.0], [1.0, 1.0]])
    m = uniform_machine(2, bw=1.0, L=0.0)
    ref = ceft_reference(g, comp, m)
    csr = ceft_jax_csr(g, comp, m)
    assert csr.path == ref.path
    np.testing.assert_array_equal(csr.pred_task, ref.pred_task)
    np.testing.assert_array_equal(csr.pred_proc, ref.pred_proc)


@given(st.integers(0, 10_000))
def test_csr_matches_reference_random(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 12))
    P = int(rng.integers(1, 5))
    g = make_random_dag(n, 0.4, rng)
    comp = rng.uniform(1, 10, size=(n, P))
    m = random_machine(P, rng, bw_range=(0.5, 2.0), L_range=(0.0, 1.0))
    _assert_equiv(g, comp, m)


# --------------------------------------------------------------- CSR structure
def test_csr_level_segments_roundtrip():
    rng = np.random.default_rng(7)
    g = make_random_dag(30, 0.3, rng)
    segs = csr_level_segments(g)
    seen = []
    for k in range(segs.n_levels):
        tasks = segs.level_tasks(k)
        seen.extend(tasks.tolist())
        assert (g.level[tasks] == k).all()
        esrc, edat, eseg = segs.level_edges(k)
        # per-child segments are contiguous, parents ascending within a segment
        assert (np.diff(eseg) >= 0).all()
        for slot, t in enumerate(tasks):
            sel = eseg == slot
            np.testing.assert_array_equal(np.sort(esrc[sel]), esrc[sel])
            np.testing.assert_array_equal(esrc[sel], g.parents(int(t)))
            np.testing.assert_array_equal(edat[sel], g.parent_data(int(t)))
    assert sorted(seen) == list(range(g.n))
    assert segs.edge_bounds[-1] == g.n_edges


# --------------------------------------------------------- bounded compilation
def test_bucketed_jit_shapes_bounded():
    """Sweeping 10 random graphs of varying size must trigger at most an
    O(log)-sized set of distinct per-level traces (pow2 buckets on vertex
    count, level width, and level edge count) -- not one trace per graph."""
    rng = np.random.default_rng(11)
    P = 4
    ns = [70, 95, 120, 150, 180, 210, 240, 300, 380, 450]
    wls = [rgg("high", n, P, rng, o=4, alpha=0.75, beta=50) for n in ns]
    before = set(CSR_TRACES)
    for wl in wls:
        ceft_jax_csr(wl.graph, wl.comp, wl.machine)
    new = set(CSR_TRACES) - before
    # naive shape handling would compile >= one sweep per graph (and the
    # per-level formulation, one per level: hundreds); buckets keep it O(log n)
    bound = 4 * int(np.ceil(np.log2(max(ns))))
    assert 0 < len(new) <= bound, (len(new), bound)

    # re-planning shape: sweeping the same graphs again (new costs) retraces
    # nothing -- every bucketed level shape is already compiled
    before = set(CSR_TRACES)
    for wl in wls:
        comp2 = wl.comp * rng.uniform(1.0, 2.0, size=wl.comp.shape[1])[None, :]
        ceft_jax_csr(wl.graph, comp2, wl.machine)
    assert len(set(CSR_TRACES) - before) == 0


# ------------------------------------------------------------------- bench JSON
def test_throughput_bench_emits_json_rows(monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_SCALE", "0.02")
    import io
    from contextlib import redirect_stdout
    from benchmarks import ceft_throughput
    rows: list = []
    buf = io.StringIO()
    with redirect_stdout(buf):
        ceft_throughput.run(json_rows=rows)
    impls = {r["impl"] for r in rows}
    assert {"reference", "vectorized", "jax_padded", "jax_csr"} <= impls
    assert any(r["bench"] == "ceft_irregular" for r in rows)
    for r in rows:
        assert r["ms"] > 0 and r["n"] > 0 and r["P"] > 0
    # CSV stays well-formed alongside the JSON mirror
    lines = buf.getvalue().strip().splitlines()
    header = lines[0].split(",")
    assert all(len(l.split(",")) == len(header) for l in lines[1:])
