"""CSR/edge-centric CEFT sweep (ISSUE 3): equivalence against the paper's
Algorithm 1 on adversarial shapes, bit-identity against the padded dense
sweep, tie-breaking, and the bounded-compilation (bucketed jit shapes)
guarantee."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    ceft,
    ceft_reference,
    csr_level_segments,
    from_edges,
    linear_chain,
    random_machine,
    uniform_machine,
)
from repro.core.ceft_jax import (
    CSR_TRACES,
    ceft_jax,
    ceft_jax_csr,
    csr_device_inputs,
)
from repro.graphs import (
    epigenomics,
    fft_graph,
    gaussian_elimination,
    heavy_tail_fan_in,
    molecular_dynamics,
    rgg,
    star_fan_in,
)
from conftest import make_random_dag


def _machine(P, seed=0):
    return random_machine(P, np.random.default_rng(seed),
                          bw_range=(0.5, 2.0), L_range=(0.0, 1.0))


def _assert_equiv(g, comp, m):
    """CSR sweep == Algorithm 1 (values, cpl, backtracked path) and
    bit-identical to the padded dense jax sweep (same f32 arithmetic)."""
    ref = ceft_reference(g, comp, m)
    pad = ceft_jax(g, comp, m)
    csr = ceft_jax_csr(g, comp, m)
    np.testing.assert_allclose(csr.ceft, ref.ceft, rtol=2e-5)
    assert csr.cpl == pytest.approx(ref.cpl, rel=2e-5)
    assert csr.path == ref.path
    np.testing.assert_array_equal(csr.ceft, pad.ceft)
    np.testing.assert_array_equal(csr.pred_task, pad.pred_task)
    np.testing.assert_array_equal(csr.pred_proc, pad.pred_proc)
    assert csr.path == pad.path and csr.cpl == pad.cpl


# ------------------------------------------------------------ adversarial shapes
def test_single_task():
    g = from_edges(1, [])
    comp = np.array([[3.0, 7.0]])
    _assert_equiv(g, comp, _machine(2))


def test_linear_chain():
    rng = np.random.default_rng(1)
    g = linear_chain(17, data=2.5)
    _assert_equiv(g, rng.uniform(1, 10, (17, 3)), _machine(3))


def test_star_fan_in_degree_much_larger_than_mean():
    rng = np.random.default_rng(2)
    g = star_fan_in(65)  # sink in-degree 64, every other in-degree 0
    assert int(g.in_degree.max()) == 64
    _assert_equiv(g, rng.uniform(1, 10, (65, 4)), _machine(4))


def test_heavy_tail_fan_in():
    rng = np.random.default_rng(3)
    g = heavy_tail_fan_in(80, rng)
    assert int(g.in_degree.max()) > 2 * float(g.in_degree.mean())
    _assert_equiv(g, rng.uniform(1, 10, (80, 3)), _machine(3))


@pytest.mark.parametrize("seed,g", [
    (101, gaussian_elimination(6)),
    (102, fft_graph(8)),
    (103, molecular_dynamics()),
    (104, epigenomics(6)),
])
def test_realworld_graphs(seed, g):
    rng = np.random.default_rng(seed)
    _assert_equiv(g, rng.uniform(1, 10, (g.n, 4)), _machine(4))


@pytest.mark.parametrize("seed,g", [
    (201, gaussian_elimination(6)),
    (202, molecular_dynamics()),
    (203, star_fan_in(33)),
])
def test_transposed_graphs(seed, g):
    """The edge-reversed graphs rank_ceft_up sweeps (paper §8.2)."""
    gt = g.transpose()
    rng = np.random.default_rng(seed)
    _assert_equiv(gt, rng.uniform(1, 10, (gt.n, 3)), _machine(3))


def test_tie_breaking_matches_reference():
    """Exactly-tied candidates (integer weights, homogeneous machine): the
    first maximal parent in ascending-id order must win, as in Algorithm 1."""
    # two parents of 3 with identical values and identical edges, twice over
    g = from_edges(4, [(0, 3, 1.0), (1, 3, 1.0), (2, 3, 1.0)])
    comp = np.array([[2.0, 2.0], [2.0, 2.0], [2.0, 2.0], [1.0, 1.0]])
    m = uniform_machine(2, bw=1.0, L=0.0)
    ref = ceft_reference(g, comp, m)
    csr = ceft_jax_csr(g, comp, m)
    assert csr.path == ref.path
    np.testing.assert_array_equal(csr.pred_task, ref.pred_task)
    np.testing.assert_array_equal(csr.pred_proc, ref.pred_proc)


@given(st.integers(0, 10_000))
def test_csr_matches_reference_random(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 12))
    P = int(rng.integers(1, 5))
    g = make_random_dag(n, 0.4, rng)
    comp = rng.uniform(1, 10, size=(n, P))
    m = random_machine(P, rng, bw_range=(0.5, 2.0), L_range=(0.0, 1.0))
    _assert_equiv(g, comp, m)


# --------------------------------------------------------------- CSR structure
def test_csr_level_segments_roundtrip():
    rng = np.random.default_rng(7)
    g = make_random_dag(30, 0.3, rng)
    segs = csr_level_segments(g)
    seen = []
    for k in range(segs.n_levels):
        tasks = segs.level_tasks(k)
        seen.extend(tasks.tolist())
        assert (g.level[tasks] == k).all()
        esrc, edat, eseg = segs.level_edges(k)
        # per-child segments are contiguous, parents ascending within a segment
        assert (np.diff(eseg) >= 0).all()
        for slot, t in enumerate(tasks):
            sel = eseg == slot
            np.testing.assert_array_equal(np.sort(esrc[sel]), esrc[sel])
            np.testing.assert_array_equal(esrc[sel], g.parents(int(t)))
            np.testing.assert_array_equal(edat[sel], g.parent_data(int(t)))
    assert sorted(seen) == list(range(g.n))
    assert segs.edge_bounds[-1] == g.n_edges


# --------------------------------------------------------- bounded compilation
def test_bucketed_jit_shapes_bounded():
    """Sweeping 10 random graphs of varying size must trigger at most an
    O(log)-sized set of distinct per-level traces (pow2 buckets on vertex
    count, level width, and level edge count) -- not one trace per graph."""
    rng = np.random.default_rng(11)
    P = 4
    ns = [70, 95, 120, 150, 180, 210, 240, 300, 380, 450]
    wls = [rgg("high", n, P, rng, o=4, alpha=0.75, beta=50) for n in ns]
    before = set(CSR_TRACES)
    for wl in wls:
        ceft_jax_csr(wl.graph, wl.comp, wl.machine)
    new = set(CSR_TRACES) - before
    # naive shape handling would compile >= one sweep per graph (and the
    # per-level formulation, one per level: hundreds); buckets keep it
    # O(log).  Fused super-steps (ISSUE 4) add a pow2 run-length axis to the
    # jit key -- a further log(depth) factor (empirically ~4 distinct run
    # buckets here), still far below one shape per level
    bound = 8 * int(np.ceil(np.log2(max(ns))))
    assert 0 < len(new) <= bound, (len(new), bound)

    # re-planning shape: sweeping the same graphs again (new costs) retraces
    # nothing -- every bucketed level shape is already compiled
    before = set(CSR_TRACES)
    for wl in wls:
        comp2 = wl.comp * rng.uniform(1.0, 2.0, size=wl.comp.shape[1])[None, :]
        ceft_jax_csr(wl.graph, comp2, wl.machine)
    assert len(set(CSR_TRACES) - before) == 0


# ------------------------------------------------------- fused super-steps (ISSUE 4)
def test_fused_superstep_equivalence_chain():
    """64 relaxation levels, all in one (W, E) bucket: the whole chain must
    sweep as fused super-steps and still match Algorithm 1 exactly."""
    rng = np.random.default_rng(40)
    g = linear_chain(65, data=1.5)
    _assert_equiv(g, rng.uniform(1, 10, (65, 3)), _machine(3))


def test_fused_superstep_equivalence_ge_like():
    """GE graphs are deep with slowly shrinking widths: runs break only at
    pow2 bucket boundaries, exercising multi-run sweeps."""
    rng = np.random.default_rng(41)
    g = gaussian_elimination(9)
    _assert_equiv(g, rng.uniform(1, 10, (g.n, 4)), _machine(4))


def test_fused_superstep_equivalence_single_level():
    """A graph with a single level (no edges at all): the fused sweep runs
    zero super-steps and the result is pure comp."""
    rng = np.random.default_rng(42)
    g = from_edges(6, [])
    comp = rng.uniform(1, 10, (6, 3))
    _assert_equiv(g, comp, _machine(3))
    res = ceft_jax_csr(g, comp, _machine(3))
    np.testing.assert_allclose(res.ceft, comp.astype(np.float32), rtol=1e-6)
    assert (res.pred_task == -1).all()


def test_superstep_fns_keyed_by_backend(monkeypatch):
    """Regression (ISSUE 5): ``jax.default_backend()`` was read once when the
    jitted super-step closures were first built, so a backend selected
    afterwards (tests forcing CPU, a GPU coming up mid-process) inherited the
    wrong donation policy.  The cache must key by backend and re-read it per
    call."""
    import jax

    from repro.core import ceft_jax as cj

    cur = jax.default_backend()
    fns_cur = cj._superstep_fns(cj.xla_edge_relax)
    assert fns_cur["donate"] == (() if cur == "cpu" else (0, 1, 2))
    # a different backend becoming default gets fresh closures + donation
    monkeypatch.setattr(cj.jax, "default_backend", lambda: "faketpu")
    fns_tpu = cj._superstep_fns(cj.xla_edge_relax)
    assert fns_tpu is not fns_cur
    assert fns_tpu["donate"] == (0, 1, 2)
    # switching back re-serves the original backend's cached entry
    monkeypatch.setattr(cj.jax, "default_backend", lambda: cur)
    assert cj._superstep_fns(cj.xla_edge_relax) is fns_cur


def test_fusion_reduces_dispatch_count_on_deep_chain():
    """A 64-level chain used to dispatch one jitted step per level from
    Python; fused same-bucket super-steps collapse it to O(1) scanned
    dispatches (and at most O(log) traces across chain depths)."""
    rng = np.random.default_rng(43)
    g = linear_chain(65)
    comp = rng.uniform(1, 10, (65, 3))
    m = _machine(3)
    inputs = csr_device_inputs(g, comp, m)
    runs = inputs[0]  # (layout, tasks, ...) per fused run
    n_dispatch = len(runs)
    n_levels_covered = sum(int(r[1].shape[0]) for r in runs)
    assert n_dispatch <= 2, f"chain not fused: {n_dispatch} dispatches"
    assert n_levels_covered >= 64  # every relaxation level is inside a run
    # the fused sweep itself still matches the unfused semantics
    _assert_equiv(g, comp, m)

    # more chains in the same (v, W, E, run-length) buckets (vertex counts
    # 58..64 all bucket to v_b=64, depths 57..63 to a run of 64): one compiled
    # super-step serves them all -- zero new traces after the first
    ceft_jax_csr(linear_chain(64), rng.uniform(1, 10, (64, 3)), m)
    before = set(CSR_TRACES)
    for n in (58, 61, 63):
        ceft_jax_csr(linear_chain(n), rng.uniform(1, 10, (n, 3)), m)
    assert len(set(CSR_TRACES) - before) == 0


def test_fuse_levels_noop_padding_rows():
    """Padded no-op levels (e_real == 0) carry only padding ids, so a scanned
    super-step can execute them without touching real DP rows."""
    g = linear_chain(8)  # 7 relaxation levels -> padded to a pow2 run of 8
    segs = csr_level_segments(g)
    from repro.core.taskgraph import fuse_levels
    widths = [8] * (segs.n_levels - 1)
    ecaps = [8] * (segs.n_levels - 1)
    runs = fuse_levels(segs, widths, ecaps, pad_vertex=99,
                       pad_run=lambda r: 8)
    (run,) = runs
    assert run.tasks.shape == (8, 8) and run.e_real[-1] == 0
    assert (run.tasks[-1] == 99).all() and (run.edge_src[-1] == 99).all()
    assert (run.edge_seg[-1] == run.width - 1).all()
    # real rows reproduce the per-level segments exactly
    for r in range(7):
        t = segs.level_tasks(r + 1)
        es, ed, eg = segs.level_edges(r + 1)
        np.testing.assert_array_equal(run.tasks[r, : len(t)], t)
        np.testing.assert_array_equal(run.edge_src[r, : len(es)], es)
        np.testing.assert_array_equal(run.edge_data[r, : len(es)], ed)
        np.testing.assert_array_equal(run.edge_seg[r, : len(es)], eg)


def test_hybrid_layout_choice():
    """The per-run layout policy: no within-level in-degree skew (chain, GE)
    -> run-local dense (R, W, D) tables; skewed fan-in (heavy tail) -> the
    O(e) segment layout.  Both are bit-identical to ceft_jax (asserted by
    the equivalence suite); this pins the policy itself."""
    rng = np.random.default_rng(50)
    m = _machine(3)

    def layouts(g):
        comp = rng.uniform(1, 10, (g.n, 3))
        return [r[0] for r in csr_device_inputs(g, comp, m)[0]]

    assert set(layouts(linear_chain(40))) == {"dense"}
    assert set(layouts(gaussian_elimination(8))) == {"dense"}
    assert "seg" in layouts(heavy_tail_fan_in(150, np.random.default_rng(51)))


def test_fuse_levels_dense_run_local_buckets():
    """Dense-layout runs are built from the CSR segments at *run-local*
    (W, D) buckets — the star graph's sink level must not pay for the
    40-wide source level, and the slot order must match the
    padded_level_tables convention (k-th slot = k-th parent ascending)."""
    from repro.core.taskgraph import fuse_levels_dense, padded_level_tables
    g = star_fan_in(41)  # level 1 = the sink: W=1, D=40
    segs = csr_level_segments(g)
    run = fuse_levels_dense(segs, 1, 2, 1, 48, pad_run=lambda r: 2)
    assert run.tasks.shape == (2, 1) and run.par.shape == (2, 1, 48)
    assert run.tasks[0, 0] == 40 and (run.tasks[1] == -1).all()  # no-op pad row
    np.testing.assert_array_equal(run.par[0, 0, :40], np.arange(40))
    assert (run.par[0, 0, 40:] == -1).all() and (run.par[1] == -1).all()
    # same slot convention as the global padded tables
    tables = padded_level_tables(g)
    np.testing.assert_array_equal(run.par[0, 0, :40], tables["par"][1, 0, :40])
    np.testing.assert_array_equal(run.pdata[0, 0, :40], tables["pdata"][1, 0, :40])
    with pytest.raises(ValueError):  # real parents must fit the caps
        fuse_levels_dense(segs, 1, 2, 1, 8)


# ------------------------------------------------------- batched CSR (ISSUE 4)
def _batch_inputs(g, B, P, rng):
    comps = rng.uniform(1, 10, (B, g.n, P)).astype(np.float32)
    Ls = rng.uniform(0, 1, (B, P)).astype(np.float32)
    bws = rng.uniform(0.5, 2, (B, P, P)).astype(np.float32)
    return comps, Ls, bws


@pytest.mark.parametrize("seed,g", [
    (301, linear_chain(33)),
    (302, gaussian_elimination(6)),
    (303, star_fan_in(40)),
    (304, heavy_tail_fan_in(60, np.random.default_rng(304))),
    (305, epigenomics(5)),
])
def test_batch_csr_bit_identical_to_batch_padded(seed, g):
    """ceft_jax_batch_csr must be bit-identical (values AND predecessor
    tables) to the vmapped padded sweep on the adversarial suite."""
    from repro.core.ceft_jax import ceft_jax_batch, ceft_jax_batch_csr
    rng = np.random.default_rng(seed)
    comps, Ls, bws = _batch_inputs(g, 3, 4, rng)
    pad = ceft_jax_batch(g, comps, Ls, bws)
    csr = ceft_jax_batch_csr(g, comps, Ls, bws)
    for a, b, name in zip(pad, csr, ["ceft", "ptask", "pproc"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_batch_csr_paths_match_reference():
    """Each batched plane, finalized, backtracks the same critical path as
    Algorithm 1 run on that plane alone."""
    from repro.core.ceft_jax import ceft_batch_csr_results
    rng = np.random.default_rng(310)
    g = gaussian_elimination(5)
    B, P = 3, 3
    comps, Ls, bws = _batch_inputs(g, B, P, rng)
    results = ceft_batch_csr_results(g, comps, Ls, bws)
    from repro.core.machine import Machine
    for b in range(B):
        m = Machine(L=np.asarray(Ls[b], np.float64),
                    bw=np.asarray(bws[b], np.float64),
                    counts=np.ones(P, np.int64))
        ref = ceft_reference(g, np.asarray(comps[b], np.float64), m)
        assert results[b].path == ref.path
        assert results[b].cpl == pytest.approx(ref.cpl, rel=2e-5)


def test_csr_batch_segments_shared_structure():
    """The segment arrays are batch-invariant; cost planes stack to (B,v,P)
    float32 and shape mismatches are rejected."""
    from repro.core.taskgraph import csr_batch_segments
    rng = np.random.default_rng(311)
    g = linear_chain(10)
    planes = [rng.uniform(1, 10, (10, 2)) for _ in range(4)]
    segs, comps = csr_batch_segments(g, planes)
    single = csr_level_segments(g)
    np.testing.assert_array_equal(segs.task_ids, single.task_ids)
    np.testing.assert_array_equal(segs.edge_src, single.edge_src)
    assert comps.shape == (4, 10, 2) and comps.dtype == np.float32
    with pytest.raises(ValueError):
        csr_batch_segments(g, rng.uniform(1, 10, (4, 9, 2)))


# ------------------------------------------------------------------- bench JSON
def test_throughput_bench_emits_json_rows(monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_SCALE", "0.02")
    import io
    from contextlib import redirect_stdout
    from benchmarks import ceft_throughput
    rows: list = []
    buf = io.StringIO()
    with redirect_stdout(buf):
        ceft_throughput.run(json_rows=rows)
    impls = {r["impl"] for r in rows}
    assert {"reference", "vectorized", "jax_padded", "jax_csr"} <= impls
    assert any(r["bench"] == "ceft_irregular" for r in rows)
    for r in rows:
        assert r["ms"] > 0 and r["n"] > 0 and r["P"] > 0
    # CSV stays well-formed alongside the JSON mirror
    lines = buf.getvalue().strip().splitlines()
    header = lines[0].split(",")
    assert all(len(l.split(",")) == len(header) for l in lines[1:])
