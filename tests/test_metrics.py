"""Metric correctness on hand-computed schedules (paper §7.3) and task-graph
structural properties."""
import numpy as np
import pytest

from repro.core import (
    Machine,
    Schedule,
    ceft,
    from_edges,
    linear_chain,
    slack,
    slr,
    speedup,
    uniform_machine,
)
from repro.core.schedule import sequential_time


def test_metrics_hand_computed():
    """Chain 0->1->2, data=1, two identical classes, bw=1, L=0.
    comp = [[2,2],[3,3],[1,1]].  Schedule all on proc 0: makespan 6."""
    g = linear_chain(3, data=1.0)
    comp = np.array([[2.0, 2.0], [3.0, 3.0], [1.0, 1.0]])
    m = uniform_machine(2)
    s = Schedule(proc=np.zeros(3, np.int64),
                 start=np.array([0.0, 2.0, 5.0]),
                 finish=np.array([2.0, 5.0, 6.0]))
    assert s.makespan == 6.0
    # sequential time = min over procs of total = 6 -> speedup 1
    assert sequential_time(comp, m) == 6.0
    assert speedup(s, comp, m) == pytest.approx(1.0)
    # CP_MIN = sum of per-task min comp = 6 -> SLR 1
    assert slr(s, g, comp) == pytest.approx(1.0)
    # chain: zero slack everywhere (t_level + b_level == M for all tasks)
    assert slack(s, g, comp, m) == pytest.approx(0.0)


def test_slack_positive_for_parallel_branch():
    """Diamond 0->{1,2}->3 where branch 2 is much shorter: it has slack."""
    g = from_edges(4, [(0, 1, 0.0), (0, 2, 0.0), (1, 3, 0.0), (2, 3, 0.0)])
    comp = np.array([[1.0], [10.0], [1.0], [1.0]])
    m = uniform_machine(1, counts=[2])
    s = Schedule(proc=np.array([0, 0, 1, 0]),
                 start=np.array([0.0, 1.0, 1.0, 11.0]),
                 finish=np.array([1.0, 11.0, 2.0, 12.0]))
    assert slack(s, g, comp, m) > 0


def test_transpose_preserves_ceft_on_symmetric_costs():
    """CEFT on G and G^T with uniform comm finds the same CPL for a chain
    (path reversal symmetry)."""
    rng = np.random.default_rng(0)
    g = linear_chain(5, data=1.0)
    comp = rng.uniform(1, 5, size=(5, 3))
    m = uniform_machine(3, bw=2.0)
    a = ceft(g, comp, m)
    gt = g.transpose()
    b = ceft(gt, comp[::-1], m)
    assert a.cpl == pytest.approx(b.cpl)


def test_padded_level_tables_roundtrip():
    from repro.core import padded_level_tables
    g = from_edges(5, [(0, 2, 1.0), (1, 2, 2.0), (2, 3, 3.0), (1, 4, 4.0)])
    t = padded_level_tables(g)
    assert t["tasks"].shape[0] == g.n_levels
    # every real task appears exactly once
    real = t["tasks"][t["tasks"] >= 0]
    assert sorted(real.tolist()) == list(range(5))
    # parent data matches the graph
    for li in range(t["tasks"].shape[0]):
        for wi, task in enumerate(t["tasks"][li]):
            if task < 0:
                continue
            ps = t["par"][li, wi]
            real_ps = ps[ps >= 0]
            assert sorted(real_ps.tolist()) == sorted(g.parents(int(task)).tolist())
