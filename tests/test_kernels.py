"""Pallas kernel validation: interpret-mode execution against the pure-jnp
oracles across shape/dtype sweeps + semiring properties + end-to-end CEFT."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ceft_relax, edge_relax, minplus, pallas_edge_relax, pallas_relax
from repro.kernels.ref import ceft_relax_ref, edge_relax_ref, minplus_ref

SHAPES_MINPLUS = [(4, 3, 5), (128, 16, 128), (300, 37, 260), (1, 1, 1),
                  (257, 129, 255), (16, 256, 16)]


@pytest.mark.parametrize("shape", SHAPES_MINPLUS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_minplus_matches_ref(shape, dtype):
    m, k, n = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    a = jnp.asarray(rng.uniform(-5, 5, (m, k)), dtype)
    b = jnp.asarray(rng.uniform(-5, 5, (k, n)), dtype)
    got = minplus(a, b)
    want = minplus_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-5)


@given(st.integers(0, 10_000))
@settings(max_examples=15)
def test_minplus_semiring_properties(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 20))
    a = jnp.asarray(rng.uniform(-5, 5, (n, n)), jnp.float32)
    # identity: I with 0 on diag, +inf off-diag
    eye = jnp.where(jnp.eye(n, dtype=bool), 0.0, 3.0e38)
    np.testing.assert_allclose(minplus(a, eye), a, rtol=1e-6)
    np.testing.assert_allclose(minplus(eye, a), a, rtol=1e-6)
    # associativity (in fp32 exact: min/plus of same values)
    b = jnp.asarray(rng.uniform(-5, 5, (n, n)), jnp.float32)
    c = jnp.asarray(rng.uniform(-5, 5, (n, n)), jnp.float32)
    left = minplus(minplus(a, b), c)
    right = minplus(a, minplus(b, c))
    np.testing.assert_allclose(left, right, rtol=1e-5, atol=1e-4)


CELL_SHAPES = [(8, 3, 4), (5, 1, 2), (16, 7, 13), (33, 9, 64), (64, 2, 128), (1, 1, 1)]


@pytest.mark.parametrize("shape", CELL_SHAPES)
def test_ceft_relax_matches_ref(shape):
    W, D, P = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    pv = jnp.asarray(rng.uniform(0, 100, (W, D, P)), jnp.float32)
    pdata = jnp.asarray(rng.uniform(0, 10, (W, D)), jnp.float32)
    validp = jnp.asarray(rng.random((W, D)) < 0.8, jnp.float32)
    L = jnp.asarray(rng.uniform(0, 2, (P,)), jnp.float32)
    bw = jnp.asarray(rng.uniform(0.5, 2, (P, P)), jnp.float32)
    got = ceft_relax(pv, pdata, validp, L, bw)
    want = ceft_relax_ref(pv, pdata, validp, L, bw)
    for g, w, name in zip(got, want, ["maxk", "argk", "argl"]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)


@given(st.integers(0, 10_000))
@settings(max_examples=10)
def test_ceft_jax_with_pallas_relax_end_to_end(seed):
    """The full DP sweep with the Pallas kernel plugged in reproduces the
    numpy Algorithm-1 results (values and the backtracked path)."""
    from repro.core import ceft, random_machine
    from repro.core.ceft_jax import ceft_jax
    from conftest import make_random_dag

    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 10))
    P = int(rng.integers(1, 5))
    g = make_random_dag(n, 0.4, rng)
    comp = rng.uniform(1, 10, size=(n, P))
    m = random_machine(P, rng, L_range=(0.0, 1.0))
    a = ceft(g, comp, m)
    b = ceft_jax(g, comp, m, relax=pallas_relax)
    np.testing.assert_allclose(b.ceft, a.ceft, rtol=2e-5)
    assert b.cpl == pytest.approx(a.cpl, rel=2e-5)


EDGE_SHAPES = [(5, 3), (128, 16), (300, 7), (1, 1), (257, 13), (64, 64)]


@pytest.mark.parametrize("shape", EDGE_SHAPES)
def test_edge_relax_matches_ref(shape):
    """Segment-tiled edge relaxation (the CSR sweep's Pallas inner loop)."""
    E, P = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    pv = jnp.asarray(rng.uniform(0, 100, (E, P)), jnp.float32)
    pdata = jnp.asarray(rng.uniform(0, 10, (E,)), jnp.float32)
    L = jnp.asarray(rng.uniform(0, 2, (P,)), jnp.float32)
    bw = jnp.asarray(rng.uniform(0.5, 2, (P, P)), jnp.float32)
    got = edge_relax(pv, pdata, L, bw)
    want = edge_relax_ref(pv, pdata, L, bw)
    for g, w, name in zip(got, want, ["minl", "argl"]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)


@given(st.integers(0, 10_000))
@settings(max_examples=10)
def test_ceft_jax_csr_with_pallas_edge_relax_end_to_end(seed):
    """The CSR DP sweep with the segment-tiled Pallas kernel plugged in
    reproduces the numpy Algorithm-1 results (values and backtracked path)."""
    from repro.core import ceft, random_machine
    from repro.core.ceft_jax import ceft_jax_csr
    from conftest import make_random_dag

    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 10))
    P = int(rng.integers(1, 5))
    g = make_random_dag(n, 0.4, rng)
    comp = rng.uniform(1, 10, size=(n, P))
    m = random_machine(P, rng, L_range=(0.0, 1.0))
    a = ceft(g, comp, m)
    b = ceft_jax_csr(g, comp, m, relax=pallas_edge_relax)
    np.testing.assert_allclose(b.ceft, a.ceft, rtol=2e-5)
    assert b.cpl == pytest.approx(a.cpl, rel=2e-5)
    assert b.path == a.path


SUPERSTEP_SHAPES = [(1, 5, 3), (4, 128, 16), (3, 300, 7), (2, 64, 64), (1, 1, 1)]


@pytest.mark.parametrize("shape", SUPERSTEP_SHAPES)
def test_edge_relax_superstep_matches_ref(shape):
    """Stacked super-step tile variant (ISSUE 4): a fused run's (R, E, P)
    edge tables relaxed in one pallas_call, vs the stacked oracle."""
    from repro.kernels import edge_relax_superstep
    from repro.kernels.ref import edge_relax_superstep_ref

    R, E, P = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    pv = jnp.asarray(rng.uniform(0, 100, (R, E, P)), jnp.float32)
    pdata = jnp.asarray(rng.uniform(0, 10, (R, E)), jnp.float32)
    L = jnp.asarray(rng.uniform(0, 2, (P,)), jnp.float32)
    bw = jnp.asarray(rng.uniform(0.5, 2, (P, P)), jnp.float32)
    got = edge_relax_superstep(pv, pdata, L, bw)
    want = edge_relax_superstep_ref(pv, pdata, L, bw)
    for g, w, name in zip(got, want, ["minl", "argl"]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)


def test_edge_relax_superstep_consistent_with_per_level():
    """Each stacked slice equals the single-level edge_relax on that slice:
    the super-step variant is the same contraction, batched over the run."""
    from repro.kernels import edge_relax_superstep

    rng = np.random.default_rng(77)
    R, E, P = 4, 96, 5
    pv = jnp.asarray(rng.uniform(0, 100, (R, E, P)), jnp.float32)
    pdata = jnp.asarray(rng.uniform(0, 10, (R, E)), jnp.float32)
    L = jnp.asarray(rng.uniform(0, 2, (P,)), jnp.float32)
    bw = jnp.asarray(rng.uniform(0.5, 2, (P, P)), jnp.float32)
    minl, argl = edge_relax_superstep(pv, pdata, L, bw)
    for r in range(R):
        m1, a1 = edge_relax(pv[r], pdata[r], L, bw)
        np.testing.assert_array_equal(np.asarray(minl[r]), np.asarray(m1))
        np.testing.assert_array_equal(np.asarray(argl[r]), np.asarray(a1))


@pytest.mark.parametrize("shape", [(8, 3, 4), (16, 7, 13)])
def test_ceft_relax_bf16(shape):
    """bf16 kernel path agrees with the bf16 oracle (TPU's native dtype)."""
    W, D, P = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    pv = jnp.asarray(rng.uniform(0, 100, (W, D, P)), jnp.bfloat16)
    pdata = jnp.asarray(rng.uniform(0, 10, (W, D)), jnp.bfloat16)
    validp = jnp.asarray(rng.random((W, D)) < 0.8, jnp.bfloat16)
    L = jnp.asarray(rng.uniform(0, 2, (P,)), jnp.bfloat16)
    bw = jnp.asarray(rng.uniform(0.5, 2, (P, P)), jnp.bfloat16)
    got = ceft_relax(pv, pdata, validp, L, bw)
    want = ceft_relax_ref(pv, pdata, validp, L, bw)
    np.testing.assert_allclose(np.asarray(got[0], np.float32),
                               np.asarray(want[0], np.float32), rtol=1e-2)
