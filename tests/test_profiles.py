"""Scoped sharding profiles: restoration guarantees, nesting, the deprecated
shim, and the concurrency regression the old global rules-table design failed
(two engines with different profiles racing on one process-wide dict)."""
import threading

import pytest

import repro.configs as C
from repro.models.common import (
    PROFILES,
    ShardingProfile,
    active_profile,
    logical_pspecs,
    resolve_profile,
    resolve_spec,
    set_sharding_profile,
    sharding_profile,
)
from repro.serve import Engine

MS = {"data": 16, "model": 16}


def test_profiles_are_immutable():
    prof = resolve_profile("serve")
    assert isinstance(prof, ShardingProfile)
    with pytest.raises(TypeError):
        prof.rules["batch"] = ("data",)


def test_context_manager_restores_on_error():
    before = active_profile()
    with pytest.raises(RuntimeError, match="boom"):
        with sharding_profile("serve"):
            assert active_profile().name == "serve"
            raise RuntimeError("boom")
    assert active_profile() is before


def test_unknown_profile_raises_without_state_change():
    before = active_profile()
    with pytest.raises(KeyError, match="unknown sharding profile"):
        with sharding_profile("no-such-profile"):
            pass  # pragma: no cover
    assert active_profile() is before


def test_nesting_inner_replaces_then_restores_outer():
    with sharding_profile("serve"):
        assert active_profile().rule("batch") == ()
        with sharding_profile("moe_ep"):
            # full replacement, not a merge: moe_ep has no batch override,
            # so batch falls back to the baseline rule, not serve's
            assert active_profile().rule("batch") == ("pod", "data")
            assert active_profile().rule("experts") == ("expert",)
        assert active_profile().rule("batch") == ()
        assert active_profile().rule("experts") == ("model",)


def test_shim_warns_and_is_overridden_by_scoped(monkeypatch):
    import repro.models.common as mc
    monkeypatch.setattr(mc, "_PROCESS_DEFAULT_PROFILE", None)
    with pytest.warns(DeprecationWarning):
        set_sharding_profile("serve")
    assert active_profile().name == "serve"
    with sharding_profile("opt1"):
        assert active_profile().name == "opt1"
    assert active_profile().name == "serve"
    # unknown name raises and leaves the default untouched
    with pytest.raises(KeyError):
        with pytest.warns(DeprecationWarning):
            set_sharding_profile("bogus")
    assert active_profile().name == "serve"


def test_threads_resolve_their_own_profiles():
    """Two threads hold different profiles *simultaneously*; each must see
    its own rules for the whole overlap (fails on the global-dict design)."""
    barrier = threading.Barrier(2, timeout=30)
    errors: list[str] = []

    def worker(name: str, expect_batch, expect_qkv):
        try:
            with sharding_profile(name):
                barrier.wait()  # both threads now inside their profile
                for _ in range(200):
                    prof = active_profile()
                    if prof.name != name:
                        errors.append(f"{name}: saw {prof.name}")
                        return
                    if prof.rule("batch") != expect_batch or \
                            prof.rule("qkv") != expect_qkv:
                        errors.append(f"{name}: wrong rules {prof.rules}")
                        return
                barrier.wait()  # hold the overlap until both finish reading
        except Exception as e:  # pragma: no cover
            errors.append(f"{name}: {e!r}")

    t1 = threading.Thread(target=worker,
                          args=("serve", (), ("model", "data")))
    t2 = threading.Thread(target=worker,
                          args=("moe_ep", ("pod", "data"), ("expert", "tp")))
    t1.start(); t2.start(); t1.join(30); t2.join(30)
    assert not errors, errors


def test_concurrent_engines_match_isolated_shardings():
    """Acceptance: two engines constructed under different active profiles in
    two threads resolve the same param pspecs as each profile selected
    alone."""
    cfg = C.get("granite-3-8b", smoke=True)

    def alone(profile):
        eng = Engine(cfg, profile=profile)
        return logical_pspecs(eng.model.specs(), MS, profile=eng.profile)

    expected = {p: alone(p) for p in ("serve", "baseline")}

    barrier = threading.Barrier(2, timeout=60)
    results: dict[str, object] = {}
    errors: list[str] = []

    def build(profile):
        try:
            with sharding_profile(profile):
                barrier.wait()
                eng = Engine(cfg)  # inherits this thread's active profile
                assert eng.profile.name == profile
                results[profile] = logical_pspecs(eng.model.specs(), MS)
        except Exception as e:  # pragma: no cover
            errors.append(f"{profile}: {e!r}")

    threads = [threading.Thread(target=build, args=(p,))
               for p in ("serve", "baseline")]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    assert not errors, errors
    assert results["serve"] == expected["serve"]
    assert results["baseline"] == expected["baseline"]
    # the two layouts genuinely differ (the race would have collapsed them)
    assert results["serve"] != results["baseline"]


def test_every_declared_profile_resolves():
    for name in PROFILES:
        prof = resolve_profile(name)
        spec = resolve_spec((256, 4096), ("batch", "ffn"), MS, profile=prof)
        assert len(spec) == 2


def test_profile_names_derive_from_registry():
    """Launcher --profile choices come from the registry (ISSUE 5): the
    helper must track PROFILES exactly, so a new profile shows up in every
    CLI without touching the launchers."""
    from repro.models.common import profile_names
    assert profile_names() == sorted(PROFILES)
    assert "serve" in profile_names() and "baseline" in profile_names()


def test_router_tenants_resolve_own_profiles_concurrently():
    """Two tenants served through the router from two threads, each micro-
    batch on an engine pinned to a different profile, both *mid-trace at the
    same time*: each trace must resolve its own profile (the thread-
    regression pattern, extended through the router's dispatch path)."""
    import numpy as np

    from repro.serve import Dispatch, EngineSlot, Request, Router

    cfg = C.get("granite-3-8b", smoke=True)
    barrier = threading.Barrier(2, timeout=60)
    seen: dict[str, str] = {}
    errors: list[str] = []

    class RecordingEngine(Engine):
        def _generate(self, prompts, scfg=None):
            seen[self.profile.name] = active_profile().name
            barrier.wait()  # both engines are inside their trace scope now
            return super()._generate(prompts, scfg)

    slots = [EngineSlot(f"eng-{p}", RecordingEngine(cfg, profile=p), p)
             for p in ("serve", "baseline")]
    router = Router(slots)
    rng = np.random.default_rng(0)

    def drive(idx, tenant):
        try:
            req = Request(tenant, rng.integers(2, cfg.vocab, 8).astype(np.int32), 2)
            d = Dispatch(engine=idx, requests=[req], wclass=req.wclass,
                         on_critical_path=False, node_prefill=0, node_decode=1)
            out = router.run_dispatch(d)
            assert out[req.rid].shape[0] >= 9
        except Exception as e:  # pragma: no cover
            errors.append(f"{tenant}: {e!r}")

    threads = [threading.Thread(target=drive, args=(i, t))
               for i, t in enumerate(("tenantA", "tenantB"))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    assert not errors, errors
    assert seen == {"serve": "serve", "baseline": "baseline"}


def test_router_observe_mid_tick_keeps_plan_cache_coherent():
    """ISSUE 6 satellite: two engine worker threads feeding observe() cost
    deltas back while the main thread ticks must not tear the plan cache's
    reverse index, and the next tick must re-plan (the deltas dirty the
    cached entry through the reverse index) instead of short-circuiting on
    the stale plan."""
    import numpy as np

    from repro.serve import Dispatch, EngineSlot, Request, Router

    cfg = C.get("granite-3-8b", smoke=True)
    barrier = threading.Barrier(3, timeout=60)
    errors: list[str] = []

    class RecordingEngine(Engine):
        def _generate(self, prompts, scfg=None):
            barrier.wait()  # both workers in-flight; main thread ticks now
            return super()._generate(prompts, scfg)

    slots = [EngineSlot(f"eng-{p}", RecordingEngine(cfg, profile=p), p)
             for p in ("serve", "baseline")]
    router = Router(slots, tick_budget=2)
    rng = np.random.default_rng(0)

    def _req(tenant, plen):
        return Request(tenant, rng.integers(2, cfg.vocab, plen).astype(np.int32), 2)

    for plen in (8, 8, 4, 4):  # two workload classes resident
        router.submit(_req("tenantQ", plen))
    assert router.tick(), "seed tick produced no dispatches"

    # worker dispatches built up-front (rng is not thread-safe)
    worker_ds = [
        Dispatch(engine=i, requests=[_req(f"tenant{i}", plen)],
                 wclass=(plen, 2), on_critical_path=False,
                 node_prefill=0, node_decode=1)
        for i, plen in enumerate((8, 4))
    ]

    def drive(d):
        try:
            out = router.run_dispatch(d)  # observe() fires on completion
            rid = d.requests[0].rid
            assert out[rid].shape[0] >= d.wclass[0] + 1
        except Exception as e:  # pragma: no cover
            errors.append(f"engine{d.engine}: {e!r}")

    threads = [threading.Thread(target=drive, args=(d,)) for d in worker_ds]
    for t in threads:
        t.start()
    barrier.wait()          # both engines are mid-generate: tick now
    router.tick()           # drains the 2 residents the seed tick left
    for t in threads:
        t.join(120)
    assert not errors, errors
    assert router.stats["invalidations"] >= 1, "observe() deltas must land"

    # pin one more delta from this thread (the raced ones may have landed
    # before the mid-flight tick planned, which would make its cached plan
    # legitimately current); now the entry is unambiguously dirty
    router.observe(0, (8, 2), 0.5, 10)
    # same class mix again: the cached plan is dirty AND its cost plane
    # changed, so the tick must re-plan, not serve the stale short-circuit
    for plen in (8, 4):
        router.submit(_req("tenantR", plen))
    plans = router.stats["plans"]
    hits = router.stats["cache_hits"]
    router.tick()
    assert router.stats["plans"] == plans + 1
    assert router.stats["cache_hits"] == hits
    # reverse index only references live plan keys (no torn state)
    pc = router.plancache
    with pc._lock:
        for keys in pc._by_class.values():
            assert keys <= set(pc._plans)
