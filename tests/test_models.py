"""Per-architecture smoke tests (deliverable f) + attention/decode properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import build
from repro.models.common import init_params
from repro.models.layers import chunked_attention
from repro.models import transformer


def _batch(cfg, rng, B=2, S=64):
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch = {"embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
                 "labels": tok,
                 "positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))}
    return batch


@pytest.mark.parametrize("arch", C.ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced config, one real train step on CPU: finite loss, params update,
    correct output shapes."""
    from repro.launch.steps import TrainStep, make_optimizer

    cfg = C.get(arch, smoke=True)
    rng = np.random.default_rng(0)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(cfg, total_steps=10)
    opt_state = opt.init(params)
    batch = _batch(cfg, rng)
    step = jax.jit(TrainStep(model, opt))
    new_p, new_s, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["loss"]) < 1.2 * np.log(cfg.vocab) + 1.0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, new_p)
    assert max(jax.tree.leaves(moved)) > 0
    # shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", C.ARCHS)
def test_arch_smoke_prefill_decode_shapes(arch):
    cfg = C.get(arch, smoke=True)
    rng = np.random.default_rng(0)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, rng, B, S)
    batch.pop("labels")
    cache, logits = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    dc = init_params(model.cache_specs(B, S), jax.random.PRNGKey(0))
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["positions"] = jnp.zeros((3, B, 1), jnp.int32)
    lg, new_cache = model.decode(params, dc, jnp.zeros((B, 1), jnp.int32),
                                 jnp.int32(0), **kwargs)
    assert lg.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert jax.tree.structure(dc) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["granite-3-8b", "glm4-9b", "mamba2-2.7b",
                                  "minicpm-2b", "whisper-tiny"])
def test_decode_matches_teacher_forcing_bf16(arch):
    """Sequential decode reproduces the teacher-forced forward within bf16
    noise for deterministic (non-MoE) families."""
    cfg = C.get(arch, smoke=True)
    rng = np.random.default_rng(1)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, T = 2, 12
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    if cfg.family == "encdec":
        from repro.models import encdec
        frames = jnp.asarray(rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
        enc_out = encdec.encode(params, cfg, frames)
        hidden, _ = encdec.decode_full(params, cfg, tok, enc_out)
        logits_full = (hidden @ params["unembed"].astype(hidden.dtype)).astype(jnp.float32)
        cache = init_params(model.cache_specs(B, T), jax.random.PRNGKey(0))
        ks, vs = jax.lax.map(lambda bp: encdec._cross_kv(bp, enc_out, cfg),
                             params["dec_blocks"])
        cache["cross"]["k"] = ks.astype(cache["cross"]["k"].dtype)
        cache["cross"]["v"] = vs.astype(cache["cross"]["v"].dtype)
    else:
        hidden, _, _ = transformer.forward_full(params, cfg, tokens=tok)
        logits_full = transformer.unembed(params, cfg, hidden)
        cache = init_params(model.cache_specs(B, T), jax.random.PRNGKey(0))
    errs = []
    for t in range(T):
        lt, cache = model.decode(params, cache, tok[:, t:t + 1], jnp.int32(t))
        diff = np.abs(np.asarray(lt[:, 0]) - np.asarray(logits_full[:, t]))
        errs.append(diff.max() / (np.abs(np.asarray(logits_full[:, t])).max() + 1e-6))
    assert max(errs) < 5e-2, (arch, max(errs))


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "dbrx-132b", "jamba-v0.1-52b"])
def test_decode_matches_teacher_forcing_moe_fp32(arch):
    """MoE families: fp32 compute + no-drop capacity makes routing stable;
    decode then matches teacher forcing to fp32 precision."""
    cfg = dataclasses.replace(C.get(arch, smoke=True),
                              capacity_factor=8.0, compute_dtype="float32")
    rng = np.random.default_rng(1)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, T = 2, 12
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    hidden, _, _ = transformer.forward_full(params, cfg, tokens=tok)
    logits_full = transformer.unembed(params, cfg, hidden)
    cache = init_params(model.cache_specs(B, T), jax.random.PRNGKey(0))
    errs = []
    for t in range(T):
        lt, cache = model.decode(params, cache, tok[:, t:t + 1], jnp.int32(t))
        diff = np.abs(np.asarray(lt[:, 0]) - np.asarray(logits_full[:, t]))
        errs.append(diff.max() / (np.abs(np.asarray(logits_full[:, t])).max() + 1e-6))
    assert max(errs) < 1e-4, (arch, max(errs))


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 7), (False, 0)])
@pytest.mark.parametrize("S", [8, 33, 64])
def test_chunked_attention_matches_naive(causal, window, S):
    """Online-softmax chunking == materialized softmax for every mask mode,
    including ragged (non-chunk-multiple) lengths."""
    rng = np.random.default_rng(S * 7 + window)
    B, Hk, G, hd = 2, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Hk, G, S, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hk, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hk, hd)), jnp.float32)
    got = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=16, k_chunk=8)
    # naive reference
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bhgqd,bkhd->bhgqk", q, k) * scale
    qpos = np.arange(S)[:, None]
    kpos = np.arange(S)[None, :]
    mask = np.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(jnp.asarray(mask)[None, None, None], s, -1e30)
    want = jnp.einsum("bhgqk,bkhd->bhgqd", jax.nn.softmax(s, axis=-1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_mrope_sections_disagree_with_rope():
    """M-RoPE with distinct (t,h,w) ids differs from vanilla RoPE, matches it
    when all three ids coincide."""
    from repro.models.layers import rope_cos_sin
    cfg = C.get("qwen2-vl-72b", smoke=True)
    B, S = 2, 8
    same = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
    c1, s1 = rope_cos_sin(cfg, same)
    c2, s2 = rope_cos_sin(dataclasses.replace(cfg, mrope=False), same[0])
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
    diff = same.at[1].add(3)
    c3, _ = rope_cos_sin(cfg, diff)
    assert np.abs(np.asarray(c3) - np.asarray(c1)).max() > 1e-3
