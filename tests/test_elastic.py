"""Elastic scaling: train on a (4,2) mesh of 8 fake devices, checkpoint, then
restore onto a *shrunk* (2,2) mesh (simulating losing half the fleet) and
continue training with identical loss trajectory.

Runs via conftest.run_isolated_script (shared with the engine-pool subprocess
tests) because the fake-device count must be set before jax initializes (the
main test process keeps the single real CPU device).
"""
from conftest import run_isolated_script

SCRIPT = """
    import os
    import numpy as np
    import jax
    from jax.sharding import Mesh
    import repro.configs as C
    from repro.configs.base import ShapeCell
    from repro.substrate import mesh_context
    from repro.train import Trainer, TrainerConfig

    cell = ShapeCell("smoke", seq_len=32, global_batch=8, kind="train")
    cfg = C.get("minicpm-2b", smoke=True)
    devs = np.array(jax.devices())

    def big_mesh():
        return Mesh(devs[:8].reshape(4, 2), ("data", "model"))

    def small_mesh():
        return Mesh(devs[:4].reshape(2, 2), ("data", "model"))

    ckpt = os.environ["CKPT_DIR"]
    # phase 1: 6 steps on the big mesh, checkpoint every 3
    t1 = Trainer(cfg, cell, TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=ckpt,
                                          log_every=1), big_mesh)
    m1 = t1.run()
    # reference run: same seed, 10 steps, big mesh throughout
    t_ref = Trainer(cfg, cell, TrainerConfig(steps=10, ckpt_every=100,
                                             ckpt_dir=ckpt + "_ref",
                                             log_every=1), big_mesh)
    ref = {m["step"]: m["loss"] for m in t_ref.run() if "loss" in m}

    # phase 2: restore the step-6 checkpoint onto the SHRUNK mesh, continue
    t2 = Trainer(cfg, cell, TrainerConfig(steps=10, ckpt_every=100,
                                          ckpt_dir=ckpt, log_every=1), small_mesh)
    p_like, o_like = t2._fresh_state()
    start, tree = t2._restore_latest(p_like, o_like)
    assert start == 7, start
    params, opt = tree["params"], tree["opt"]
    import jax.numpy as jnp
    for step in range(7, 11):
        batch = t2.data.sharded_batch(step - 1, t2.in_sh)
        with mesh_context(t2.mesh):
            params, opt, m = t2.step_fn(params, opt, batch)
        loss = float(m["loss"])
        r = ref[step]
        # cross-mesh reduction order shifts fp32 sums ~0.3%; same-mesh
        # exactness is asserted in test_recovery_reproduces_unfailed_run
        assert abs(loss - r) / abs(r) < 2e-2, (step, loss, r)
    print("ELASTIC_OK")
"""


def test_elastic_reshard(tmp_path):
    run_isolated_script(SCRIPT, fake_devices=8,
                        env={"CKPT_DIR": str(tmp_path / "ck")},
                        marker="ELASTIC_OK")
