"""The placement plane (ISSUE 7): EnginePool lifecycle, measured comm plane,
bit-identity of pool-routed plans to the direct-engine Router, failure as
degradation (worker loss -> degraded column -> failover re-plan), autoscale,
and the subprocess worker backend."""
import os
import signal
import time

import numpy as np
import pytest

from conftest import run_isolated_script
from repro.core.ceft_jax import plan_request_dag
from repro.sched.straggler import LOST_SLOWDOWN, EwmaCostTable, StragglerMonitor
from repro.serve import (
    EnginePool,
    EngineSlot,
    Request,
    Router,
    ServeConfig,
    WorkerLost,
    WorkerSpec,
    router_machine,
)


class FakeEngine:
    def __init__(self):
        self.calls = []

    def generate(self, prompts, scfg):
        B, P = prompts.shape
        self.calls.append((B, P))
        return np.full((B, P + scfg.max_new_tokens), 7, np.int32)


class DyingEngine(FakeEngine):
    """Serves ``survive`` calls, then dies like a crashed worker process."""

    def __init__(self, name, index, survive=0):
        super().__init__()
        self.name, self.index, self.survive = name, index, survive

    def generate(self, prompts, scfg):
        if len(self.calls) >= self.survive:
            raise WorkerLost(self.name, self.index, "killed under load")
        return super().generate(prompts, scfg)


def _slots(P, engine_cls=FakeEngine):
    return [EngineSlot(f"e{i}", engine_cls(), "baseline") for i in range(P)]


def _submit_mixed(router, rng, per_class=4, classes=(8, 16), max_new=4):
    for t, plen in enumerate(classes):
        for _ in range(per_class):
            prompt = rng.integers(2, 100, plen).astype(np.int32)
            assert router.submit(Request(f"t{t}", prompt, max_new))


def _seed_rates(router, rng, classes=((8, 4), (16, 4)), P=2):
    for wc in classes:
        for e in range(P):
            router.costs.update(wc, e, float(rng.uniform(0.5e-3, 3e-3)))


# -------------------------------------------------------------- static plane
def test_from_slots_static_machine_matches_proxy():
    """The compat path keeps PR 5's proxy plane byte-for-byte: a fixed
    snapshot over from_slots equals router_machine exactly."""
    pool = EnginePool.from_slots(_slots(3))
    proxy = router_machine(3)
    m = pool.machine()
    assert np.array_equal(m.L, proxy.L)
    assert np.array_equal(m.bw, proxy.bw)
    assert np.array_equal(m.counts, proxy.counts)
    assert pool.machine() is m          # snapshot is cached, not rebuilt


def test_pool_routed_plans_bit_identical_to_direct_router():
    """Acceptance (ISSUE 7): for a fixed pool snapshot, plans routed through
    EnginePool are bit-identical to the direct-engine Router — same dispatch
    decisions, same swept plan, and both equal the unbatched reference sweep
    on the router's own DAG."""
    results = []
    for wrap in (False, True):
        slots = _slots(2)
        router = Router(EnginePool.from_slots(slots) if wrap else slots)
        rng = np.random.default_rng(11)
        _seed_rates(router, rng)
        _submit_mixed(router, rng)
        ds = router.tick()
        results.append((router, [(d.engine, d.wclass, len(d.requests),
                                  d.on_critical_path) for d in ds]))
    (r_direct, seq_direct), (r_pool, seq_pool) = results
    assert seq_direct == seq_pool
    assert np.array_equal(r_direct.last_plan.ceft, r_pool.last_plan.ceft)
    assert r_direct.last_plan.path == r_pool.last_plan.path
    n, src, dst, data, comp = r_pool.last_dag
    ref = plan_request_dag(n, src, dst, data, comp, r_pool.machine)
    assert np.array_equal(r_pool.last_plan.ceft, ref.ceft)
    assert r_pool.last_plan.path == ref.path


# ------------------------------------------------------------ measured plane
def test_measured_probes_build_quantized_machine():
    """Injected deterministic probes: class-pair bandwidth composes the two
    measured legs (parent-relayed handoff) and lands on the sqrt2 grid; the
    snapshot object is stable until a measurement crosses a bucket."""
    legs = {0: 2.0 ** 18, 1: 2.0 ** 18}   # tokens/s per worker leg

    def probe(member, payload):
        i = int(member.spec.name[1:])
        return (len(payload) // 4) / legs[i]

    pool = EnginePool([WorkerSpec(f"e{i}", engine=FakeEngine())
                       for i in range(2)], probe=probe, bw_alpha=1.0)
    pool.refresh_probes()
    m1 = pool.machine()
    # pair rate = 1/(1/2^18 + 1/2^18) = 2^17, exactly on the grid
    assert m1.bw[0, 1] == pytest.approx(2.0 ** 17)
    assert m1.bw[1, 0] == pytest.approx(2.0 ** 17)
    # re-probing identical legs keeps the SAME snapshot object
    pool.refresh_probes()
    assert pool.machine() is m1
    # a 4x faster leg crosses the quantization bucket: new snapshot, and
    # listeners get the superseded one (the plan-cache invalidation hook)
    events = []
    pool.add_listener(lambda ev, payload: events.append((ev, payload)))
    legs[1] = 2.0 ** 20
    pool.refresh_probes()
    m2 = pool.machine()
    assert m2 is not m1
    assert m2.bw[0, 1] > m1.bw[0, 1]
    assert ("machine", m1) in events


def test_measured_probe_delta_triggers_router_replan():
    """A comm-plane delta that moves the Machine snapshot must invalidate the
    cached plan (machine-fingerprint scope) and force a re-plan on the next
    tick — stale-machine plans may never short-circuit."""
    legs = {0: 2.0 ** 18, 1: 2.0 ** 18}

    def probe(member, payload):
        return (len(payload) // 4) / legs[int(member.spec.name[1:])]

    pool = EnginePool([WorkerSpec(f"e{i}", engine=FakeEngine())
                       for i in range(2)], probe=probe, bw_alpha=1.0)
    pool.refresh_probes()
    router = Router(pool)
    rng = np.random.default_rng(12)
    _seed_rates(router, rng)
    _submit_mixed(router, rng)
    router.tick()
    assert router.stats["plans"] == 1
    # steady state: same mix, unchanged plane -> cache hit, no new plan
    _submit_mixed(router, rng)
    router.tick()
    assert router.stats["plans"] == 1 and router.stats["cache_hits"] >= 1
    # the measured plane moves a bucket: the next tick must re-plan
    legs[0] = 2.0 ** 22
    pool.refresh_probes()
    inv_before = router.stats["invalidations"]
    _submit_mixed(router, rng)
    router.tick()
    assert router.stats["plans"] == 2
    assert router.stats["invalidations"] > inv_before


def test_topology_reported_through_substrate_seam():
    from repro.substrate import process_topology

    pool = EnginePool.from_slots(_slots(2))
    topo = pool.topology()
    here = process_topology()
    assert len(topo) == 2
    for t in topo:
        assert t["host"] == here["host"] and t["pid"] == os.getpid()


# -------------------------------------------------------- failure semantics
def test_worker_loss_degrades_column_and_fails_over():
    """Acceptance (ISSUE 7): killing a worker under load completes the
    in-flight workload via the degraded-plane re-plan — the lost worker's
    pending requests requeue, its class column goes fully degraded, and the
    survivors serve everything; the loss carries per-engine context."""
    slots = [EngineSlot("e0", FakeEngine(), "baseline"),
             EngineSlot("e1", DyingEngine("e1", 1, survive=1), "baseline")]
    router = Router(slots, max_batch=1)   # one request per dispatch
    rng = np.random.default_rng(13)
    # e1 is the cheap engine: the single-class critical path pins to it, so
    # the whole workload is genuinely in flight on the worker that dies
    router.costs.update((16, 4), 0, 2e-3)
    router.costs.update((16, 4), 1, 1e-3)
    for _ in range(4):
        router.submit(Request("t", rng.integers(2, 100, 16).astype(np.int32), 4))
    done = router.serve()
    assert len(done) == 4, "in-flight workload must complete on survivors"
    # e1 finished exactly one dispatch before dying; that result was KEPT
    # and the survivor served the three requeued requests
    assert len(slots[1].engine.calls) == 1
    assert len(slots[0].engine.calls) == 3
    assert router.pool.state(1) == "lost"
    assert [name for name, _ in router.failures] == ["e1"]
    (name, err), = router.failures
    assert isinstance(err, WorkerLost) and err.index == 1
    assert "e1" in str(err) and "killed under load" in str(err)
    assert router.stats["requeued"] > 0
    # the lost column is fully degraded -> degraded-plane re-plans fired
    assert router._slow[1] >= LOST_SLOWDOWN
    assert router.stats["degraded_plans"] >= 1
    # and the next planned tick maps the critical path off the lost worker
    _submit_mixed(router, rng, per_class=2)
    ds = router.tick()
    assert ds and all(d.engine == 0 for d in ds)
    assert set(dict(router.last_plan.path).values()) == {0}


def test_all_workers_lost_raises_with_context():
    slots = [EngineSlot(f"e{i}", DyingEngine(f"e{i}", i, survive=0), "baseline")
             for i in range(2)]
    router = Router(slots)
    rng = np.random.default_rng(14)
    _seed_rates(router, rng)
    _submit_mixed(router, rng, per_class=2)
    with pytest.raises(RuntimeError, match="no live pool workers") as ei:
        router.serve()
    assert {name for name, _ in ei.value.failures} == {"e0", "e1"}


def test_generate_on_lost_worker_raises_worker_lost():
    pool = EnginePool.from_slots(_slots(2))
    pool.mark_lost(1)
    with pytest.raises(WorkerLost, match="e1"):
        pool.generate(1, np.zeros((1, 4), np.int32), ServeConfig(max_new_tokens=2))
    # index 0 still serves
    out = pool.generate(0, np.zeros((1, 4), np.int32), ServeConfig(max_new_tokens=2))
    assert out.shape == (1, 6)


def test_launch_revives_freed_slot_in_place():
    """Lost/drained workers keep their class column; a launch reuses the
    freed slot (index-stable columns) and revives the straggler column."""
    pool = EnginePool.from_slots(_slots(3))
    router = Router(pool)
    pool.mark_lost(1)
    assert router._slow[1] >= LOST_SLOWDOWN       # listener degraded it
    assert pool.size == 3 and pool.live_indices() == [0, 2]
    idx = pool.launch(WorkerSpec("e1b", engine=FakeEngine()))
    assert idx == 1 and pool.live_indices() == [0, 1, 2]
    assert pool.slots[1].name == "e1b"
    router._sync_pool()
    assert router._slow[1] == 1.0                 # revived column is nominal
    assert pool.machine().P == 3


# ---------------------------------------------------------------- autoscale
def test_autoscale_scales_out_and_drains_on_queue_depth():
    pool = EnginePool([WorkerSpec("e0", engine=FakeEngine())],
                      autoscale=True, max_size=3, high_water=4, low_water=0)
    events = []
    pool.add_listener(lambda ev, payload: events.append((ev, payload)))
    assert pool.maybe_autoscale(40) == "out"
    assert pool.maybe_autoscale(40) == "out"
    assert pool.maybe_autoscale(40) is None       # at max_size
    assert len(pool.live_indices()) == 3
    assert pool.machine().P == 3
    assert pool.stats["scale_out"] == 2
    # backlog gone: autoscaled workers drain back to min_size, last first
    assert pool.maybe_autoscale(0) == "in"
    assert pool.maybe_autoscale(0) == "in"
    assert pool.maybe_autoscale(0) is None        # at min_size
    assert len(pool.live_indices()) == 1
    assert [e for e, _ in events].count("launch") == 2
    assert [e for e, _ in events].count("drain") == 2


def test_router_tick_drives_autoscale():
    pool = EnginePool([WorkerSpec("e0", engine=FakeEngine())],
                      autoscale=True, max_size=2, high_water=2, low_water=0)
    router = Router(pool)
    rng = np.random.default_rng(15)
    _submit_mixed(router, rng, per_class=8)       # 16 pending > high_water
    router.tick()
    assert len(pool.live_indices()) == 2
    assert router.costs.n_classes == 2            # cost table grew with P


# ------------------------------------- straggler/cost-table elastic (bugfix)
def test_straggler_report_for_unseen_engine_registers_degraded_column():
    """Regression (ISSUE 7): a slowdown report for an engine the monitor has
    never seen (just-launched / just-lost worker) must register a degraded
    column instead of raising."""
    mon = StragglerMonitor(2, threshold=1.3)
    mon.observe(np.ones(2))
    slow = mon.report(4, 3.0)                     # index 4 never seen
    assert len(slow) == 5 and slow[4] == pytest.approx(3.0)
    assert slow[0] == 1.0 and slow[1] == 1.0      # existing columns untouched
    slow = mon.mark_lost(7)                       # loss of an unseen worker
    assert len(slow) == 8 and slow[7] >= LOST_SLOWDOWN
    # observing a prefix keeps the wider columns' estimates
    slow = mon.observe(np.asarray([1.0, 1.0]))
    assert len(slow) == 8 and slow[7] >= LOST_SLOWDOWN
    assert slow[4] == pytest.approx(3.0)


def test_cost_table_update_for_unseen_engine_grows_rows():
    """Regression (ISSUE 7): a measured rate for an engine index beyond the
    table's width (a just-launched worker) widens every row instead of
    raising IndexError."""
    t = EwmaCostTable(2, default=1e-3)
    t.update((8, 4), 0, 2e-3)
    t.update((8, 4), 5, 4e-3)                     # engine 5 never existed
    assert t.n_classes == 6
    row = t.row((8, 4))
    assert len(row) == 6
    assert row[0] == pytest.approx(2e-3) and row[5] == pytest.approx(4e-3)
    # pre-existing rows widened too: unobserved tail falls back to row mean
    assert np.isfinite(t.row((8, 4))).all()
    t2 = EwmaCostTable(2)
    t2.update((1, 1), 1, 1.0)
    t2.ensure_classes(4)
    assert len(t2.row((1, 1))) == 4


def test_cost_table_reset_class_forgets_one_column():
    t = EwmaCostTable(2, default=1e-3)
    t.update((8, 4), 0, 2e-3)
    t.update((8, 4), 1, 8e-3)
    t.reset_class(1)
    row = t.row((8, 4))
    assert row[0] == pytest.approx(2e-3)
    assert row[1] == pytest.approx(2e-3)          # falls back to observed mean


# --------------------------------------------------------- subprocess backend
def test_subprocess_worker_roundtrip_and_measured_plane():
    pool = EnginePool(
        [WorkerSpec("w0", factory="repro.serve.pool:null_engine_factory",
                    backend="subprocess")], probe="measure")
    try:
        out = pool.generate(0, np.ones((2, 4), np.int32),
                            ServeConfig(max_new_tokens=3))
        assert out.shape == (2, 7) and (out == 0).all()
        # the child reports its own process identity through the seam
        topo = pool.topology()[0]
        assert topo["pid"] != os.getpid()
        pool.refresh_probes()
        m = pool.machine()
        assert np.isfinite(m.bw).all() and (m.bw > 0).all()
        assert pool.stats["probes"] >= 1
    finally:
        pool.close()


def test_subprocess_worker_death_surfaces_as_worker_lost():
    pool = EnginePool(
        [WorkerSpec("w0", factory="repro.serve.pool:null_engine_factory",
                    backend="subprocess")])
    pid = pool.worker_pid(0)
    assert pid is not None
    os.kill(pid, signal.SIGKILL)
    time.sleep(0.2)
    with pytest.raises(WorkerLost, match="w0"):
        pool.generate(0, np.ones((1, 4), np.int32),
                      ServeConfig(max_new_tokens=2))
    assert pool.state(0) == "lost"
    assert pool.stats["lost"] == 1


POOL_E2E = """
    import numpy as np
    from repro.serve import EnginePool, Request, Router, WorkerSpec

    specs = [WorkerSpec(f"w{i}", factory="repro.serve.pool:null_engine_factory",
                        backend="subprocess") for i in range(2)]
    pool = EnginePool(specs, probe="measure")
    pool.refresh_probes()
    router = Router(pool)
    rng = np.random.default_rng(0)
    for plen in (8, 16):
        for _ in range(3):
            router.submit(Request("t", rng.integers(2, 100, plen).astype(np.int32), 4))
    done = router.serve()
    assert len(done) == 6, len(done)
    assert router.stats["plans"] >= 1
    pool.close()
    assert pool.live_indices() == []
    print("POOL_OK")
"""


def test_subprocess_pool_end_to_end():
    """Two subprocess workers behind the Router, probed comm plane, full
    serve loop — run through the shared isolated-script bootstrap (the same
    helper the elastic-reshard test uses)."""
    run_isolated_script(POOL_E2E, marker="POOL_OK", timeout=300)


# ------------------------------------------- shutdown + protocol (ISSUE 8)
def test_close_escalates_sigkill_on_stopped_child_and_reaps():
    """Regression (ISSUE 8 satellite): close() on a SIGSTOP'd child must
    escalate to SIGKILL, reap the process (no zombie) and close both pipe
    fds — a hung worker cannot leak across drain+relaunch cycles."""
    pool = EnginePool(
        [WorkerSpec("w0", factory="repro.serve.pool:null_engine_factory",
                    backend="subprocess")])
    handle = pool._members[0].handle
    handle.close_timeout = 0.3          # keep the graceful grace short
    pid = pool.worker_pid(0)
    os.kill(pid, signal.SIGSTOP)        # the child can never reply or exit
    t0 = time.monotonic()
    pool.drain(0)                       # -> handle.close()
    assert time.monotonic() - t0 < 5.0, "close blocked on a stopped child"
    assert handle.proc.returncode is not None, "child was not reaped"
    assert handle.proc.returncode < 0   # killed by signal, not clean exit
    assert handle.proc.stdin.closed and handle.proc.stdout.closed
    # reaped: the pid no longer exists (or is at worst a different process)
    with pytest.raises(ProcessLookupError):
        os.kill(pid, 0)


def test_recv_msg_rejects_malformed_frames():
    """Satellite (ISSUE 8): garbage fed straight into the framing layer must
    surface as typed errors (FrameError / EOFError), never a hang or a
    misparse."""
    import io

    from repro.serve.pool import FrameError, _recv_msg, _send_msg

    # short header -> EOF
    with pytest.raises(EOFError, match="pipe closed"):
        _recv_msg(io.BytesIO(b"\x01\x02"))
    # absurd length header (random corrupt bytes) -> FrameError via the cap
    with pytest.raises(FrameError, match="exceeds cap"):
        _recv_msg(io.BytesIO(b"\xde\xad\xbe\xef\xde\xad\xbe\xef"))
    # valid header, truncated body -> EOF with byte counts
    import struct
    with pytest.raises(EOFError, match="truncated frame: 3/9"):
        _recv_msg(io.BytesIO(struct.pack("<Q", 9) + b"abc"))
    # full-length garbage payload -> FrameError, not a raw pickle error
    with pytest.raises(FrameError, match="corrupt frame payload"):
        _recv_msg(io.BytesIO(struct.pack("<Q", 4) + b"\x00\x01\x02\x03"))
    # a well-formed frame still round-trips
    buf = io.BytesIO()
    _send_msg(buf, ("ok", 42))
    buf.seek(0)
    assert _recv_msg(buf) == ("ok", 42)


def test_corrupt_stream_surfaces_as_worker_lost_with_context():
    """Satellite (ISSUE 8): a corrupt protocol stream (garbage written into
    the live pipe) surfaces as WorkerLost naming the engine — not a hang,
    not a raw EOFError."""
    pool = EnginePool(
        [WorkerSpec("w0", factory="repro.serve.pool:null_engine_factory",
                    backend="subprocess")])
    try:
        handle = pool._members[0].handle
        handle.proc.stdin.write(b"\xde\xad\xbe\xef" * 4)
        handle.proc.stdin.flush()
        with pytest.raises(WorkerLost, match="w0"):
            pool.generate(0, np.ones((1, 4), np.int32),
                          ServeConfig(max_new_tokens=2))
        assert pool.state(0) == "lost"
    finally:
        pool.close()


def test_reply_matching_drops_stale_lower_seq_frames():
    """Satellite (ISSUE 8): the parent matches replies by sequence id — a
    duplicated/late reply frame (lower seq) is dropped and counted, a
    skipped-ahead seq is a desync and raises."""
    import io

    from repro.serve.pool import FrameError, _SubprocWorker, _send_msg

    w = object.__new__(_SubprocWorker)
    w.stats = {"stale_replies": 0}
    w.proc = type("P", (), {})()
    buf = io.BytesIO()
    _send_msg(buf, (1, "ok", "stale"))      # duplicate of an old reply
    _send_msg(buf, (1, "ok", "stale2"))     # ...twice
    _send_msg(buf, (3, "ok", "fresh"))
    buf.seek(0)
    w.proc.stdout = buf
    assert w._reply_for(3) == (3, "ok", "fresh")
    assert w.stats["stale_replies"] == 2
    buf2 = io.BytesIO()
    _send_msg(buf2, (9, "ok", "from the future"))
    buf2.seek(0)
    w.proc.stdout = buf2
    with pytest.raises(FrameError, match="protocol desync"):
        w._reply_for(4)


# ------------------------------------------------- relaunch budget (ISSUE 8)
def test_relaunch_budget_backoff_and_exhaustion():
    """Tentpole (ISSUE 8): a crash-looping worker is relaunched under
    bounded exponential backoff at most relaunch_budget times, then
    converges to permanently-degraded (stays LOST, column routed around)."""
    pool = EnginePool.from_slots(_slots(2), relaunch_budget=2,
                                 relaunch_backoff=10.0)
    pool.mark_lost(0)
    assert pool.relaunchable() == [0]
    assert pool.maybe_relaunch(0, now=0.0)          # attempt 1: immediate
    assert pool.live_indices() == [0, 1]
    assert pool.stats["relaunches"] == 1
    pool.mark_lost(0)
    assert not pool.maybe_relaunch(0, now=5.0)      # inside backoff window
    assert pool.state(0) == "lost"
    assert pool.maybe_relaunch(0, now=25.0)         # attempt 2 (= budget)
    assert pool.stats["relaunch_exhausted"] == 1
    pool.mark_lost(0)
    assert pool.relaunchable() == []                # budget spent
    assert not pool.maybe_relaunch(0, now=1e9)
    assert pool.state(0) == "lost"                  # permanently degraded
    assert pool.live_indices() == [1]


def test_failed_relaunch_consumes_budget_and_stays_lost():
    # build the pool around a live fake, then make its spec unbuildable
    pool2 = EnginePool.from_slots(_slots(1), relaunch_budget=1)
    pool2._members[0].spec = WorkerSpec("w0", factory="nosuch.module:nothing")
    pool2.mark_lost(0)
    assert not pool2.maybe_relaunch(0, now=0.0)     # factory import fails
    assert pool2.state(0) == "lost"
    assert pool2.stats["relaunches"] == 0
    assert pool2.relaunchable() == []               # the attempt was spent


def test_router_serve_relaunches_lost_worker_between_ticks():
    """The armed serve loop revives budget-eligible lost slots each tick."""
    pool = EnginePool.from_slots(_slots(2), relaunch_backoff=0.01)
    router = Router(pool, deadline_factor=50.0, min_deadline=10.0)
    pool.mark_lost(1)
    rng = np.random.default_rng(31)
    _submit_mixed(router, rng, per_class=2)
    done = router.serve(max_ticks=50)
    assert len(done) == 4
    assert pool.stats["relaunches"] >= 1
    assert pool.live_indices() == [0, 1]
