"""Scheduler validity and metric properties for HEFT / CPOP / CEFT-CPOP and
the CEFT-HEFT rank variants."""
import numpy as np
import pytest
from _hyp import given, st

from repro.core import (
    ceft,
    ceft_cpop,
    ceft_heft_down,
    ceft_heft_up,
    cpop,
    heft,
    heft_down,
    min_comp_critical_path,
    random_machine,
    slack,
    slr,
    speedup,
    validate_schedule,
)
from repro.core.cpop import cpop_cpl
from conftest import make_random_dag

ALGOS = [heft, heft_down, cpop, ceft_cpop, ceft_heft_up, ceft_heft_down]


def _workload(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 24))
    P = int(rng.integers(1, 5))
    g = make_random_dag(n, 0.3, rng)
    comp = rng.uniform(1, 10, size=(n, P))
    counts = rng.integers(1, 3, size=P)
    m = random_machine(P, rng, counts=counts, L_range=(0.0, 0.5))
    return g, comp, m


@given(st.integers(0, 10_000))
def test_schedules_are_valid(seed):
    g, comp, m = _workload(seed)
    for algo in ALGOS:
        s = algo(g, comp, m)
        validate_schedule(s, g, comp, m)


@given(st.integers(0, 10_000))
def test_metric_invariants(seed):
    g, comp, m = _workload(seed)
    cp_min, _ = min_comp_critical_path(g, comp)
    for algo in ALGOS:
        s = algo(g, comp, m)
        assert s.makespan >= cp_min - 1e-9          # CP_MIN is a lower bound
        assert slr(s, g, comp) >= 1.0 - 1e-9        # eq. 9
        assert speedup(s, comp, m) > 0
        assert slack(s, g, comp, m) >= -1e-6         # eq. 10 is non-negative


@given(st.integers(0, 10_000))
def test_makespan_dominates_ceft_cpl_modulo_availability(seed):
    """CEFT's CPL is a dependence-only lower bound: any schedule of the graph
    on the machine must take at least ... NOTE: CEFT assumes task duplication,
    so it can undercut a no-duplication schedule but never exceed the
    CEFT-CPOP realized makespan."""
    g, comp, m = _workload(seed)
    res = ceft(g, comp, m)
    s = ceft_cpop(g, comp, m, res)
    assert s.makespan >= res.cpl * 0.999 or s.makespan >= res.cpl - 1e-6


def test_cpop_cpl_is_single_proc_sum():
    rng = np.random.default_rng(1)
    g = make_random_dag(10, 0.3, rng)
    comp = rng.uniform(1, 10, size=(10, 3))
    m = random_machine(3, rng)
    v = cpop_cpl(g, comp, m)
    # must equal some column-sum over a path's tasks: at minimum it is
    # >= (min column sum over any single task) and <= sum of max costs
    assert 0 < v <= comp.max(axis=1).sum()


def test_specialization_scenario_ceft_cpop_beats_cpop():
    """Bimodal tasks on specialized classes with cheap comm: pinning the CP to
    one processor (CPOP) pays the mismatch penalty; CEFT-CPOP does not."""
    rng = np.random.default_rng(0)
    n = 12
    from repro.core import from_edges
    g = from_edges(n, [(i, i + 1, 1e-6) for i in range(n - 1)])
    comp = np.empty((n, 2))
    comp[::2] = [1.0, 50.0]
    comp[1::2] = [50.0, 1.0]
    m = random_machine(2, rng, bw_range=(1e5, 1e6))
    mk_ours = ceft_cpop(g, comp, m).makespan
    mk_cpop = cpop(g, comp, m).makespan
    assert mk_ours < mk_cpop
    assert mk_ours == pytest.approx(n * 1.0, rel=0.2)
