"""Workload generators: structure invariants and the paper's exact counts."""
import numpy as np
import pytest
from _hyp import given, st

from repro.graphs import (
    epigenomics,
    fft_graph,
    gaussian_elimination,
    molecular_dynamics,
    rgg,
)
from repro.graphs.rgg import INTERVALS, classic_workload, interval_workload


@given(st.integers(0, 1000), st.sampled_from(["classic", "low", "medium", "high"]))
def test_rgg_structure(seed, kind):
    rng = np.random.default_rng(seed)
    n = int(rng.choice([32, 64, 128]))
    P = int(rng.choice([2, 4, 8]))
    wl = rgg(kind, n, P, rng, o=4, c=1.0,
             alpha=float(rng.choice([0.25, 0.75, 1.0])),
             beta=float(rng.choice([10, 50, 95])),
             gamma=float(rng.choice([0.1, 0.5])))
    g = wl.graph
    assert g.n == n
    assert wl.comp.shape == (n, P)
    assert (wl.comp > 0).all()
    # every non-level-0 vertex has a parent (connectivity invariant)
    assert (g.in_degree[g.level > 0] > 0).all()
    # edge data all positive
    assert (g.cdata > 0).all()


def test_classic_heterogeneity_bound():
    """eq. (5): w_ij in w_i * [1 - b/2, 1 + b/2] -- at most 3x spread."""
    rng = np.random.default_rng(0)
    wl = rgg("classic", 128, 8, rng, beta=95.0)
    ratio = wl.comp.max(axis=1) / wl.comp.min(axis=1)
    assert (ratio <= 3.0 + 1e-9).all()


def test_interval_heterogeneity_grows():
    """RGG-high expresses (much) more heterogeneity than RGG-low."""
    rng = np.random.default_rng(0)
    lo = rgg("low", 256, 8, rng, beta=50.0)
    hi = rgg("high", 256, 8, rng, beta=50.0)
    r_lo = np.median(lo.comp.max(axis=1) / lo.comp.min(axis=1))
    r_hi = np.median(hi.comp.max(axis=1) / hi.comp.min(axis=1))
    assert r_hi > 2 * r_lo


@pytest.mark.parametrize("m,expected", [(5, 14), (8, 35), (10, 54)])
def test_gaussian_elimination_count(m, expected):
    """(m^2 + m - 2) / 2 tasks (paper §7.2.2; m=5 -> 14 as in Fig. 3a)."""
    g = gaussian_elimination(m)
    assert g.n == expected
    assert len(g.sources) == 1 and len(g.sinks) == 1


@pytest.mark.parametrize("m", [4, 8, 16])
def test_fft_counts(m):
    """2m-1 recursive calls + m*log2(m) butterflies (paper §7.2.1)."""
    g = fft_graph(m)
    lg = int(np.log2(m))
    assert g.n == 2 * m - 1 + m * lg
    assert len(g.sources) == 1
    assert len(g.sinks) == m


def test_fft_all_paths_equal_length():
    """'All the paths in this application are the critical-path' (§7.2.1)."""
    g = fft_graph(8)
    from repro.core.bruteforce import all_paths
    lengths = {len(p) for p in all_paths(g)}
    assert len(lengths) == 1


def test_molecular_dynamics_fixed():
    g = molecular_dynamics()
    assert g.n == 41
    assert g.n_edges > 60  # irregular, dense-ish


@pytest.mark.parametrize("B", [4, 8])
def test_epigenomics_structure(B):
    g = epigenomics(B)
    assert g.n == 4 * B + 4
    assert len(g.sources) == 1 and len(g.sinks) == 1
    # wide & shallow: B parallel 4-chains
    assert g.n_levels == 8  # split + 4 stages + merge + index + pileup
