"""Backward deadline propagation over planned CEFT schedules (ISSUE 9).

The acceptance property, checked over the graph zoo: the propagation is
bit-consistent with the CEFT plan — every task's planned schedule under the
mapped classes dominates its own DP value (``planned_finish >= ceft[t, a(t)]``,
hence ``makespan >= cpl``), at ``slo = makespan`` slack is non-negative with
the zero-slack set the mapped critical path, and whenever the partial
schedule extends to a full one without lengthening (``makespan == cpl``) the
paper's critical path is EXACTLY a zero-slack chain.  Latest times are
affine in the horizon (no re-propagation on SLO shifts)."""
import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import make_random_dag
from repro.core import ceft, linear_chain, random_machine, uniform_machine
from repro.sched import DeadlineSchedule, plan_classes, propagate_deadlines

EPS = 1e-9


def _zoo(n, p_edge, P, seed):
    rng = np.random.default_rng(seed)
    g = make_random_dag(n, p_edge, rng)
    m = random_machine(P, rng, bw_range=(0.2, 5.0), L_range=(0.0, 0.5))
    comp = rng.uniform(0.5, 4.0, (n, P))
    return g, comp, m


def _check_consistency(g, comp, m):
    """The full property bundle for one (graph, comp, machine) instance."""
    res = ceft(g, comp, m)
    D = propagate_deadlines(g, comp, m, res)
    tol = EPS * max(1.0, abs(D.makespan))
    cls = plan_classes(res)
    # the mapping honours the path's own partial assignment
    for t, p in res.assignment.items():
        assert cls[t] == p
    # planned schedule dominates the DP row it was mapped from
    assert (D.planned_finish + tol >= res.ceft[np.arange(g.n), cls]).all()
    assert D.makespan >= res.cpl - 1e-6 * max(1.0, res.cpl)
    # intrinsic slack (slo = makespan): non-negative, zero on a real path
    assert D.slo == D.makespan and D.feasible
    assert (D.slack >= -tol).all()
    assert D.critical().any(), "some task must be critical"
    assert (D.latest_finish <= D.makespan + tol).all()
    assert np.allclose(D.planned_finish, D.planned_start + comp[
        np.arange(g.n), cls], atol=1e-12)
    # mutual inclusivity, serving-side: when the partial schedule extended
    # to a full one without lengthening, the DP's critical path IS the
    # zero-slack chain
    if abs(D.makespan - res.cpl) <= 1e-6 * max(1.0, res.cpl):
        crit = D.critical(eps=1e-6)
        for t, p in res.path:
            assert crit[t], f"path task {t} has slack {D.slack[t]}"
            assert cls[t] == p
    return res, D


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 18), st.sampled_from([0.15, 0.3, 0.6]),
       st.integers(1, 4), st.integers(0, 10_000))
def test_propagation_consistent_with_plan_zoo(n, p_edge, P, seed):
    g, comp, m = _zoo(n, p_edge, P, seed)
    _check_consistency(g, comp, m)


def test_propagation_consistent_fixed_instances():
    """Deterministic fallback for the zoo property (runs without hypothesis):
    chains, fan-in, and a handful of random DAGs."""
    rng = np.random.default_rng(0)
    for n, p_edge, P, seed in ((2, 0.3, 1, 1), (6, 0.15, 2, 2),
                               (10, 0.3, 3, 3), (14, 0.6, 4, 4)):
        g, comp, m = _zoo(n, p_edge, P, seed)
        _check_consistency(g, comp, m)
    g = linear_chain(6, data=2.0)
    comp = rng.uniform(0.5, 4.0, (6, 3))
    m = random_machine(3, rng, bw_range=(0.2, 5.0), L_range=(0.0, 0.5))
    res, D = _check_consistency(g, comp, m)
    # a chain is all critical path: every vertex has zero slack
    assert D.critical().all()
    assert D.makespan == pytest.approx(res.cpl, rel=1e-9)


def test_affine_shift_matches_repropagation():
    """latest_*(slo') == latest_*(slo) + (slo' - slo): shifting a cached
    schedule must equal re-propagating at the new horizon."""
    g, comp, m = _zoo(12, 0.3, 3, 42)
    res = ceft(g, comp, m)
    D = propagate_deadlines(g, comp, m, res)
    D2 = propagate_deadlines(g, comp, m, res, slo=D.makespan + 3.5)
    assert np.allclose(D2.latest_start, D.latest_start + 3.5, atol=1e-12)
    assert np.allclose(D2.latest_finish, D.latest_finish + 3.5, atol=1e-12)
    assert np.allclose(D2.slack, D.slack + 3.5, atol=1e-12)
    # planned times do not move with the horizon
    assert np.array_equal(D2.planned_start, D.planned_start)
    # latest_finish_for IS that shift, per task
    for t in range(g.n):
        assert D.latest_finish_for(t, D.slo + 3.5) == pytest.approx(
            float(D2.latest_finish[t]), abs=1e-12)


def test_infeasible_slo_reports_negative_slack():
    g, comp, m = _zoo(8, 0.3, 2, 7)
    res = ceft(g, comp, m)
    D = propagate_deadlines(g, comp, m, res, slo=0.5 * ceft(g, comp, m).cpl)
    assert not D.feasible
    assert (D.slack < 0).any()
    # and a generous slo is slack everywhere
    D2 = propagate_deadlines(g, comp, m, res, slo=10.0 * D.makespan)
    assert D2.feasible and (D2.slack > 0).all()


def test_sink_slos_min_combined_and_tighten_upstream():
    """Per-sink overrides: a tighter sink deadline propagates upstream, and
    a vertex carrying both the global horizon and an override takes the min."""
    g = linear_chain(4)
    comp = np.full((4, 2), 1.0)
    m = uniform_machine(2)
    res = ceft(g, comp, m)
    D = propagate_deadlines(g, comp, m, res)
    tight = D.makespan - 0.75
    D2 = propagate_deadlines(g, comp, m, res, sink_slos={3: tight})
    assert float(D2.latest_finish[3]) == pytest.approx(tight)
    # the whole upstream chain tightened by the same amount
    assert np.allclose(D2.latest_finish, D.latest_finish - 0.75, atol=1e-12)
    # min-combination: an override LOOSER than the horizon is ignored
    D3 = propagate_deadlines(g, comp, m, res,
                             sink_slos={3: D.makespan + 5.0})
    assert np.array_equal(D3.latest_finish, D.latest_finish)


def test_comp_shape_mismatch_raises():
    g = linear_chain(3)
    m = uniform_machine(2)
    res = ceft(g, np.ones((3, 2)), m)
    with pytest.raises(ValueError, match="comp has"):
        propagate_deadlines(g, np.ones((4, 2)), m, res)


def test_feasible_accounts_for_comm_between_classes():
    """A two-class fan where the mapping forces a cross-class hop: the
    propagation must charge the DP's own comm rule (L + data/bw), not zero."""
    from repro.core import from_edges

    g = from_edges(3, [(0, 2, 4.0), (1, 2, 4.0)])
    # comp forces vertex 0 -> class 0, vertex 1 -> class 1, vertex 2 -> class 0
    comp = np.array([[1.0, 9.0], [9.0, 1.0], [1.0, 9.0]])
    m = random_machine(2, np.random.default_rng(3), bw_range=(1.0, 1.0),
                       L_range=(0.25, 0.25))
    res = ceft(g, comp, m)
    D = propagate_deadlines(g, comp, m, res)
    cls = D.classes
    assert cls[0] != cls[1], "setup: parents must map to different classes"
    hop = float(m.L[cls[1]] + 4.0 / m.bw[cls[1], cls[2]])
    # vertex 2 cannot start before the cross-class parent's finish + hop
    assert float(D.planned_start[2]) >= float(D.planned_finish[1]) + hop - EPS
