"""Deterministic fault-injection harness + chaos soak (ISSUE 8): seeded
schedules replay exactly, each fault kind fails the way real infrastructure
fails, and the soak acceptance — under seeded kills/hangs/delays/duplicates
on a 4-worker pool every admitted request completes exactly once with hedge
work bounded by the overdue critical-path dispatch count."""
import numpy as np
import pytest

from repro.serve import EnginePool, EngineSlot, Request, Router, ServeConfig, WorkerLost
from repro.serve.faults import KINDS, Fault, FaultInjector, FaultPlan, install_chaos


class FakeEngine:
    def __init__(self):
        self.calls = []

    def generate(self, prompts, scfg):
        B, P = prompts.shape
        self.calls.append((B, P))
        return np.full((B, P + scfg.max_new_tokens), 7, np.int32)


def _pool(P=4, **kw):
    slots = [EngineSlot(f"e{i}", FakeEngine(), "baseline") for i in range(P)]
    return EnginePool.from_slots(slots, **kw)


# ------------------------------------------------------------------- plans
def test_seeded_plan_is_deterministic():
    a = FaultPlan.seeded(7, 4, calls=10, rate=0.3)
    b = FaultPlan.seeded(7, 4, calls=10, rate=0.3)
    assert a._by_slot == {k: v for k, v in b._by_slot.items()}
    assert len(a) > 0
    c = FaultPlan.seeded(8, 4, calls=10, rate=0.3)
    assert a._by_slot != c._by_slot


def test_unknown_fault_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(0, 1, "meteor")


def test_pop_consumes_fault_once():
    plan = FaultPlan().add(0, 1, "delay", 0.01)
    assert plan.pop(0, 1).kind == "delay"
    assert plan.pop(0, 1) is None


# ------------------------------------------------------------- fault kinds
def test_kill_and_drop_surface_as_worker_lost():
    pool = _pool(2, relaunch_budget=0)
    plan = FaultPlan().add(0, 1, "kill").add(1, 1, "drop")
    inj = FaultInjector(plan).install(pool)
    scfg = ServeConfig(max_new_tokens=2)
    with pytest.raises(WorkerLost, match="injected kill"):
        pool.generate(0, np.zeros((1, 4), np.int32), scfg)
    with pytest.raises(WorkerLost, match="injected reply drop"):
        pool.generate(1, np.zeros((1, 4), np.int32), scfg)
    # both losses went through the pool's normal degradation path
    assert pool.state(0) == "lost" and pool.state(1) == "lost"
    assert inj.stats["kill"] == 1 and inj.stats["drop"] == 1


def test_delay_forwards_after_stall():
    pool = _pool(1)
    FaultInjector(FaultPlan().add(0, 1, "delay", 0.01)).install(pool)
    out = pool.generate(0, np.zeros((1, 4), np.int32),
                        ServeConfig(max_new_tokens=2))
    assert out.shape == (1, 6)       # the call still completes


def test_hang_blocks_until_released():
    pool = _pool(1)
    inj = FaultInjector(FaultPlan().add(0, 1, "hang"),
                        hang_timeout=30.0).install(pool)
    import threading
    err = []

    def call():
        try:
            pool.generate(0, np.zeros((1, 4), np.int32),
                          ServeConfig(max_new_tokens=2))
        except WorkerLost as e:
            err.append(e)

    t = threading.Thread(target=call, daemon=True)
    t.start()
    t.join(timeout=0.1)
    assert t.is_alive(), "hang must actually block"
    inj.release()
    t.join(timeout=5.0)
    assert not t.is_alive() and err and "injected hang" in str(err[0])


def test_wrapper_transparent_for_slots_and_passthrough():
    pool = _pool(2)
    FaultInjector(FaultPlan()).install(pool)
    # pool.slots must still expose the underlying engine objects
    assert all(isinstance(s.engine, FakeEngine) for s in pool.slots)
    out = pool.generate(0, np.zeros((1, 4), np.int32),
                        ServeConfig(max_new_tokens=2))
    assert out.shape == (1, 6)


# --------------------------------------------------------------- chaos soak
def _submit(router, rng, per_class=6, classes=(8, 16), max_new=4):
    rids = []
    for t, plen in enumerate(classes):
        for _ in range(per_class):
            r = Request(f"t{t}", rng.integers(2, 100, plen).astype(np.int32),
                        max_new)
            assert router.submit(r)
            rids.append(r.rid)
    return rids


@pytest.mark.parametrize("seed", [7, 23])
def test_chaos_soak_every_request_completes_exactly_once(seed):
    """Acceptance (ISSUE 8): seeded kills, hangs, delays, drops and
    duplicated replies on a 4-worker pool — zero lost requests, zero
    double-completions, hedges bounded by overdue critical-path count."""
    pool = _pool(4, relaunch_backoff=0.05, relaunch_backoff_max=0.2)
    inj = install_chaos(pool, seed, calls=8, rate=0.5, hold=0.3)
    inj.hang_timeout = 5.0
    router = Router(pool, deadline_factor=3.0, min_deadline=0.05,
                    wd_poll=0.005, max_batch=4)
    rng = np.random.default_rng(seed)
    rids = _submit(router, rng)
    try:
        done = router.serve(max_ticks=500)
    finally:
        inj.release()
    assert set(done) == set(rids), (
        f"lost {sorted(set(rids) - set(done))} under chaos seed {seed}")
    # exactly once: every completion in `done` was a FIRST completion, and
    # duplicate attempts were dropped as stale, not double-counted
    assert router.stats["completions"] == len(rids)
    assert router.stats["hedges"] <= max(router.stats["overdue_cp"], 0)
    # the schedule actually fired faults (otherwise this soaks nothing)
    fired = sum(inj.stats[k] for k in KINDS)
    assert fired >= 3, inj.stats
    # tokens are the engines' deterministic output, trimmed per request
    for rid in rids:
        assert (done[rid] == 7).all()
