"""Plan-derived deadline watchdog + hedged re-dispatch (ISSUE 8): strike
escalation with an injected clock, armed-but-quiet bit-identity to the
disarmed router, the escalation ladder end-to-end on a hanging worker, and
stale-reply rejection when a hedged original recovers late."""
import threading
import time

import numpy as np
import pytest

from repro.sched.straggler import LOST_SLOWDOWN, StragglerMonitor
from repro.serve import (
    DeadlineWatchdog,
    EnginePool,
    EngineSlot,
    Request,
    Router,
)
from repro.serve.faults import FaultInjector, FaultPlan
from repro.serve.watchdog import InflightEntry


class FakeEngine:
    def __init__(self):
        self.calls = []

    def generate(self, prompts, scfg):
        B, P = prompts.shape
        self.calls.append((B, P))
        return np.full((B, P + scfg.max_new_tokens), 7, np.int32)


class HangingEngine(FakeEngine):
    """Hangs (until ``release``) on its first call, then serves normally —
    the unreachable-worker case the watchdog exists for."""

    def __init__(self):
        super().__init__()
        self.release = threading.Event()

    def generate(self, prompts, scfg):
        first = not self.calls
        out = super().generate(prompts, scfg)
        if first:
            self.release.wait(timeout=30.0)
        return out


def _slots(P, engines=None):
    engines = engines or [FakeEngine() for _ in range(P)]
    return [EngineSlot(f"e{i}", e, "baseline") for i, e in enumerate(engines)]


def _submit(router, rng, per_class=4, classes=(8, 16), max_new=4):
    rids = []
    for t, plen in enumerate(classes):
        for _ in range(per_class):
            r = Request(f"t{t}", rng.integers(2, 100, plen).astype(np.int32),
                        max_new)
            assert router.submit(r)
            rids.append(r.rid)
    return rids


# ----------------------------------------------------------- watchdog unit
def test_budget_is_floor_clamped():
    wd = DeadlineWatchdog(deadline_factor=3.0, min_deadline=0.05)
    assert wd.budget(1.0) == pytest.approx(3.0)
    # microsecond smoke spans must not turn timer noise into false alarms
    assert wd.budget(1e-6) == pytest.approx(0.05)


def test_sweep_strikes_once_per_budget_with_injected_clock():
    t = [0.0]
    fired: list[tuple[int, int]] = []
    wd = DeadlineWatchdog(deadline_factor=2.0, min_deadline=0.0,
                          clock=lambda: t[0],
                          on_overdue=lambda e, now: fired.append(
                              (e.seq, e.strikes)))
    e = wd.arm(1, "payload", planned_span=1.0, engine=0,
               on_critical_path=True)
    assert isinstance(e, InflightEntry) and e.deadline == pytest.approx(2.0)
    t[0] = 1.9
    assert wd.sweep() == []                    # inside budget: quiet
    t[0] = 2.1
    assert [x.seq for x in wd.sweep()] == [1]  # strike 1
    t[0] = 2.2
    assert wd.sweep() == []                    # pushed deadline: one strike
    t[0] = 4.2                                 # ...per budget, not per poll
    assert [x.strikes for x in wd.sweep()] == [2]
    assert fired == [(1, 1), (1, 2)]
    assert wd.disarm(1) is e and wd.inflight() == 0
    t[0] = 99.0
    assert wd.sweep() == []                    # disarmed entries never fire
    assert wd.stats["armed"] == 1 and wd.stats["completed"] == 1
    assert wd.stats["overdue"] == 2
    assert wd.disarm(1) is None                # idempotent


def test_arm_explicit_budget_replaces_flat_multiple():
    """ISSUE 9: an explicit budget (the router's SLO-propagated latest-
    finish) replaces the flat deadline_factor x span for the deadline AND
    every later strike push, floor-clamped by min_deadline."""
    t = [0.0]
    wd = DeadlineWatchdog(deadline_factor=3.0, min_deadline=0.05,
                          clock=lambda: t[0])
    # flat would be 3.0 x 100 = 300s; the propagated budget wins
    e = wd.arm(1, None, planned_span=100.0, engine=0,
               on_critical_path=False, budget=0.5)
    assert e.budget == pytest.approx(0.5)
    assert e.deadline == pytest.approx(0.5)
    t[0] = 0.6
    assert [x.seq for x in wd.sweep()] == [1]
    assert e.deadline == pytest.approx(1.1)    # pushed by ITS OWN budget
    t[0] = 1.2
    assert [x.strikes for x in wd.sweep()] == [2]
    # a blown SLO degrades to the min_deadline floor, never a zero budget
    e2 = wd.arm(2, None, planned_span=1.0, engine=0,
                on_critical_path=False, budget=-3.0)
    assert e2.budget == pytest.approx(0.05)
    # budget=None keeps the historical flat behaviour byte for byte
    e3 = wd.arm(3, None, planned_span=1.0, engine=0, on_critical_path=False)
    assert e3.budget == pytest.approx(3.0)


def test_monitor_thread_fires_on_real_clock():
    fired = threading.Event()
    wd = DeadlineWatchdog(deadline_factor=1.0, min_deadline=0.01,
                          poll_interval=0.005,
                          on_overdue=lambda e, now: fired.set())
    wd.arm(1, None, planned_span=0.0, engine=0, on_critical_path=False)
    wd.start()
    try:
        assert fired.wait(timeout=2.0), "monitor thread never swept"
    finally:
        wd.stop()
    assert wd.stats["sweeps"] >= 1


def test_report_overdue_trips_threshold_monotonically():
    mon = StragglerMonitor(3, threshold=1.3)
    mon.observe(np.ones(3))
    slow = mon.report_overdue(1)
    assert slow[1] == pytest.approx(1.3)       # at least the threshold
    slow = mon.report_overdue(1, 2.5)
    assert slow[1] == pytest.approx(2.5)
    slow = mon.report_overdue(1, 1.1)          # never REDUCES degradation
    assert slow[1] == pytest.approx(2.5)
    mon.mark_lost(2)
    slow = mon.report_overdue(2)               # lost columns stay lost
    assert slow[2] >= LOST_SLOWDOWN


# ------------------------------------------------- armed-but-quiet identity
def test_armed_router_plans_bit_identical_when_no_faults():
    """Acceptance (ISSUE 8): with the watchdog armed but nothing overdue,
    plans and dispatch decisions on a fixed snapshot are bit-identical to
    the disarmed (PR 7) router — tick() is untouched by the watchdog."""
    results = []
    for armed in (False, True):
        router = Router(_slots(2),
                        deadline_factor=50.0 if armed else None,
                        min_deadline=10.0)
        rng = np.random.default_rng(21)
        for wc in ((8, 4), (16, 4)):
            for e in range(2):
                router.costs.update(wc, e, float(rng.uniform(0.5e-3, 3e-3)))
        _submit(router, rng)
        ds = router.tick()
        results.append((router, [(d.engine, d.wclass, len(d.requests),
                                  d.on_critical_path) for d in ds]))
    (r_plain, seq_plain), (r_armed, seq_armed) = results
    assert seq_plain == seq_armed
    assert np.array_equal(r_plain.last_plan.ceft, r_armed.last_plan.ceft)
    assert r_plain.last_plan.path == r_armed.last_plan.path
    assert r_plain.last_plan.assignment == r_armed.last_plan.assignment


def test_armed_serve_quiet_completes_with_zero_overdue():
    router = Router(_slots(2), deadline_factor=50.0, min_deadline=10.0)
    rng = np.random.default_rng(22)
    rids = _submit(router, rng)
    done = router.serve()
    assert set(done) == set(rids)
    assert router.stats["overdue"] == 0
    assert router.stats["hedges"] == 0
    assert router.stats["completions"] == len(rids)
    assert router.watchdog.stats["armed"] == router.stats["dispatches"]
    assert router.watchdog.inflight() == 0


# --------------------------------------------------------- escalation ladder
def test_hanging_worker_walks_ladder_hedge_requeue_lost():
    """Acceptance (ISSUE 8 tentpole): a hung critical-path worker is hedged
    to the degraded plane's alternate (strike 1), its work requeued (strike
    2), and the worker marked lost (strike 3) — every admitted request still
    completes exactly once, and hedge work stays bounded by the overdue
    critical-path dispatch count."""
    hanging = HangingEngine()
    engines = [hanging, FakeEngine()]
    pool = EnginePool.from_slots(_slots(2, engines), relaunch_budget=0)
    router = Router(pool, deadline_factor=3.0, min_deadline=0.05,
                    wd_poll=0.005, max_batch=8)
    # e0 is the cheap engine: the critical path pins there, so the hang is
    # genuinely a critical-path stall
    for wc in ((8, 4), (16, 4)):
        router.costs.update(wc, 0, 1e-3)
        router.costs.update(wc, 1, 2e-3)
    rng = np.random.default_rng(23)
    rids = _submit(router, rng)
    try:
        done = router.serve(max_ticks=200)
    finally:
        hanging.release.set()
    assert set(done) == set(rids), "every admitted request completes"
    assert router.stats["completions"] == len(rids)      # exactly once
    assert router.stats["overdue_cp"] >= 1
    assert 1 <= router.stats["hedges"] <= router.stats["overdue_cp"]
    assert router.stats["watchdog_lost"] >= 1
    assert pool.state(0) == "lost"                       # strike 3 fired
    assert len(engines[1].calls) >= 1                    # survivors served
    # repeat offender was report()ed: its column is degraded or lost
    assert router.monitor.slowdowns()[0] >= router.monitor.threshold


def test_stale_reply_from_late_recovering_original_is_dropped():
    """Satellite (ISSUE 8): a hedged critical-path task whose original
    worker recovers LATE (the injector's duplicate-reply fault) has the
    duplicate completion dropped by rid — counted in stats["stale_replies"],
    never double-completed."""
    slots = _slots(2)
    pool = EnginePool.from_slots(slots, relaunch_budget=0)
    # worker 0's first generate: do the work, hold the reply 0.6s, return it
    # late -- by then the hedge has won the race
    plan = FaultPlan().add(0, 1, "dup", 0.6)
    FaultInjector(plan).install(pool)
    router = Router(pool, deadline_factor=3.0, min_deadline=0.05,
                    wd_poll=0.005, max_batch=8)
    for e, rate in ((0, 1e-3), (1, 2e-3)):   # CP pins to worker 0
        router.costs.update((8, 4), e, rate)
    rng = np.random.default_rng(24)
    rids = _submit(router, rng, per_class=2, classes=(8,))
    done = router.serve(max_ticks=200)
    assert set(done) == set(rids)
    assert router.stats["completions"] == len(rids)      # no double-complete
    assert router.stats["hedges"] >= 1
    assert router.stats["hedges"] <= router.stats["overdue_cp"]
    assert router.stats["stale_replies"] >= 1            # the late duplicate
    # both attempts really ran: the original did the work before holding
    assert len(slots[0].engine.calls) >= 1
    assert len(slots[1].engine.calls) >= 1
