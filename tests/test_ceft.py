"""CEFT correctness: the paper's invariants, cross-implementation agreement,
and reductions to classical longest paths."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    ceft,
    ceft_reference,
    chain_cost,
    from_edges,
    linear_chain,
    min_comp_critical_path,
    random_machine,
    uniform_machine,
)
from repro.core.bruteforce import bruteforce_cpl, chain_optimal_cost, all_paths
from repro.core.ceft_jax import ceft_jax
from conftest import make_random_dag


def _workload(seed, n_max=8, p_max=4):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, n_max))
    P = int(rng.integers(1, p_max))
    g = make_random_dag(n, 0.4, rng)
    comp = rng.uniform(1, 10, size=(n, P))
    m = random_machine(P, rng, bw_range=(0.5, 2.0), L_range=(0.0, 1.0))
    return g, comp, m


@given(st.integers(0, 10_000))
def test_vectorized_matches_reference(seed):
    g, comp, m = _workload(seed)
    a = ceft_reference(g, comp, m)
    b = ceft(g, comp, m)
    np.testing.assert_allclose(a.ceft, b.ceft, rtol=1e-12)
    assert a.cpl == pytest.approx(b.cpl)
    assert a.path == b.path


@given(st.integers(0, 10_000))
def test_jax_matches_numpy(seed):
    g, comp, m = _workload(seed)
    a = ceft(g, comp, m)
    b = ceft_jax(g, comp, m)
    np.testing.assert_allclose(a.ceft, b.ceft, rtol=2e-5)
    assert b.cpl == pytest.approx(a.cpl, rel=2e-5)


@given(st.integers(0, 10_000))
def test_cpl_dominates_every_path_optimum(seed):
    """CEFT >= chain-optimal cost of every source->sink path (the recurrence
    is min-max >= max-min; §4.1)."""
    g, comp, m = _workload(seed, n_max=7)
    res = ceft(g, comp, m)
    bf = bruteforce_cpl(g, comp, m)
    assert res.cpl >= bf - 1e-9


@given(st.integers(0, 10_000))
def test_path_value_is_exact_chain_cost(seed):
    """The returned path + partial assignment reproduces the claimed CPL
    exactly (the 'mutual inclusivity' of path and partial schedule)."""
    g, comp, m = _workload(seed)
    res = ceft(g, comp, m)
    assert chain_cost(res.path, g, comp, m) == pytest.approx(res.cpl, rel=1e-9)


@given(st.integers(0, 10_000))
def test_homogeneous_reduces_to_longest_path(seed):
    """One processor class: CEFT == classical longest path with comm=0 (same
    class => co-located)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 10))
    g = make_random_dag(n, 0.3, rng)
    comp = rng.uniform(1, 10, size=(n, 1))
    m = uniform_machine(1)
    res = ceft(g, comp, m)
    lp, _ = min_comp_critical_path(g, comp)
    assert res.cpl == pytest.approx(lp)


@given(st.integers(0, 10_000))
def test_free_comm_reduces_to_min_comp_longest_path(seed):
    """Infinite bandwidth + zero startup: per-task min comp, classical DP."""
    g, comp, _ = _workload(seed)
    P = comp.shape[1]
    m = uniform_machine(P, bw=1e30, L=0.0)
    res = ceft(g, comp, m)
    lp, _ = min_comp_critical_path(g, comp)
    assert res.cpl == pytest.approx(lp, rel=1e-6)


def test_linear_chain_exact():
    """On a chain the CEFT CPL equals the exact chain DP optimum."""
    rng = np.random.default_rng(3)
    g = linear_chain(6, data=2.0)
    comp = rng.uniform(1, 10, size=(6, 3))
    m = random_machine(3, rng, L_range=(0.0, 0.5))
    res = ceft(g, comp, m)
    opt = chain_optimal_cost(list(range(6)), g, comp, m)
    assert res.cpl == pytest.approx(opt)
    assert [t for t, _ in res.path] == list(range(6))


def test_assignment_exploits_specialization():
    """Two task types x two specialized classes: CEFT assigns each task to its
    fast class when comm is cheap (the paper's motivating scenario)."""
    g = linear_chain(4, data=0.001)
    comp = np.array([[1.0, 100.0], [100.0, 1.0], [1.0, 100.0], [100.0, 1.0]])
    m = uniform_machine(2, bw=1e6)
    res = ceft(g, comp, m)
    assert [p for _, p in res.path] == [0, 1, 0, 1]
    assert res.cpl == pytest.approx(4.0, abs=0.1)


def test_single_processor_pinning_when_comm_dominates():
    """Huge comm costs: the optimal chain stays on one class."""
    g = linear_chain(4, data=1e9)
    rng = np.random.default_rng(0)
    comp = rng.uniform(1, 3, size=(4, 3))
    m = uniform_machine(3, bw=1.0)
    res = ceft(g, comp, m)
    classes = {p for _, p in res.path}
    assert len(classes) == 1
    assert res.cpl == pytest.approx(comp[:, list(classes)[0]].sum())


def test_multiple_sinks_takes_longest():
    edges = [(0, 1, 1.0), (0, 2, 1.0)]
    g = from_edges(3, edges)
    comp = np.array([[1.0], [5.0], [2.0]])
    res = ceft(g, comp, uniform_machine(1))
    assert res.cpl == pytest.approx(6.0)
    assert res.sink == 1
