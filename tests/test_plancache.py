"""Unified plan cache (repro.sched.plancache): bit-identity of partial
dirty-frontier re-sweeps vs from-scratch sweeps on adversarial multi-run
graphs, reverse-index invalidation, LRU eviction, trace-grid reuse and
concurrent plan/invalidate safety (ISSUE 6)."""
import threading

import numpy as np
import pytest

from repro.core import Machine, from_edges, uniform_machine
from repro.core.ceft_jax import CSR_TRACES, ceft_jax_csr
from repro.sched import PlanCache
from repro.sched import plancache as PC


#: adversarial shape: alternating wide plateaus and width-1 tails defeat the
#: fuse-waste heuristic into FOUR fused runs (spans (1,6),(6,10),(10,14),
#: (14,18)), so dirty-frontier resume engages at several distinct depths.  A
#: uniform layered graph fuses into a single run and every delta degenerates
#: to a full sweep.
WIDTHS = (64,) + (1,) * 5 + (64,) + (1,) * 5 + (64,) + (1,) * 5


def _layered_graph(rng, widths=WIDTHS, max_par=3):
    """Layered DAG with <= ``max_par`` parents per vertex, random weights."""
    starts, edges, base = [], [], 0
    for w in widths:
        starts.append(base)
        base += w
    n = base
    for li in range(1, len(widths)):
        lo, w = starts[li], widths[li]
        plo, pw = starts[li - 1], widths[li - 1]
        for v in range(lo, lo + w):
            k = min(pw, int(rng.integers(1, max_par + 1)))
            for u in rng.choice(pw, size=k, replace=False):
                edges.append((plo + int(u), v, float(rng.uniform(0.5, 4.0))))
    return from_edges(n, edges), np.asarray(starts)


def _machine(P=3):
    return uniform_machine(P, bw=1.0, L=0.1)


def _assert_bit_identical(res, ref):
    np.testing.assert_array_equal(res.ceft, ref.ceft)
    np.testing.assert_array_equal(res.pred_task, ref.pred_task)
    np.testing.assert_array_equal(res.pred_proc, ref.pred_proc)
    assert res.sink == ref.sink and res.sink_proc == ref.sink_proc
    assert res.cpl == ref.cpl
    assert res.path == ref.path and res.assignment == ref.assignment


def test_graph_splits_into_multiple_runs():
    """Precondition for everything below: the adversarial shape must produce
    >= 2 fused runs past the folded level-0 init."""
    g, _ = _layered_graph(np.random.default_rng(0))
    _, _, _, spans = PC.device_state(g)
    assert len(spans) >= 3, spans
    # spans tile the non-source levels contiguously from level 1
    assert spans[0][0] == 1
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c


@pytest.mark.parametrize("where", ["deep", "mid", "source"])
def test_cost_delta_resweeps_are_bit_identical(where):
    """A changed cost plane re-sweeps from its dirty frontier only, and the
    result is bit-identical to a from-scratch sweep: deep deltas resume a
    late run (partial), mid deltas an earlier one, source deltas force a
    full sweep (level 0 is folded into the init)."""
    rng = np.random.default_rng(1)
    g, starts = _layered_graph(rng)
    m = _machine()
    comp = rng.uniform(1, 10, (g.n, m.P))
    pc = PlanCache()
    res0, status0, _ = pc.plan(g, comp, m)
    assert status0 == "full"
    _assert_bit_identical(res0, ceft_jax_csr(g, comp, m))

    comp2 = comp.copy()
    # deep: last run's tail; mid: second run; source: level 0 (folded into
    # the init — any delta there must force a full sweep)
    row = {"deep": int(starts[16]), "mid": int(starts[7]), "source": 0}[where]
    comp2[row] *= 1.7
    res2, status2, _ = pc.plan(g, comp2, m)
    assert status2 == ("full" if where == "source" else "partial")
    _assert_bit_identical(res2, ceft_jax_csr(g, comp2, m))
    assert pc.snapshot()["hits"] == 0


def test_chained_partials_and_straggler_flip_bit_identical():
    """partial -> partial -> column-rescale (straggler flip: every level
    dirty => full) -> partial again, each bit-identical to from-scratch."""
    rng = np.random.default_rng(2)
    g, starts = _layered_graph(rng)
    m = _machine()
    comp = rng.uniform(1, 10, (g.n, m.P))
    pc = PlanCache()
    pc.plan(g, comp, m)

    expected = {"full_sweeps": 1, "partial_sweeps": 0}
    deltas = {0: 6, 1: 10, 3: 15}  # levels in runs 1, 2 and 3
    for step in range(4):
        if step == 2:  # straggler flip: one class column 2.3x slower
            slow = np.ones(m.P)
            slow[1] = 2.3
            comp = comp * slow[None, :]
            expected["full_sweeps"] += 1
            want = "full"
        else:  # point deltas at increasing depth
            comp = comp.copy()
            comp[int(starts[deltas[step]])] *= float(rng.uniform(1.1, 3.0))
            expected["partial_sweeps"] += 1
            want = "partial"
        res, status, _ = pc.plan(g, comp, m)
        assert status == want, (step, status)
        _assert_bit_identical(res, ceft_jax_csr(g, comp, m))
    snap = pc.snapshot()
    assert snap["full_sweeps"] == expected["full_sweeps"]
    assert snap["partial_sweeps"] == expected["partial_sweeps"]


def test_arrival_departure_churn_bit_identical():
    """Different graphs (arrivals/departures change the DAG) get independent
    entries; revisiting an earlier graph+plane is a pure hit and every plan
    stays bit-identical to from-scratch."""
    rng = np.random.default_rng(3)
    m = _machine()
    pc = PlanCache()
    graphs = []
    for tail in (3, 5, 7):  # churn: the request tail grows/shrinks
        g, _ = _layered_graph(rng, widths=WIDTHS[:13] + (1,) * tail)
        comp = rng.uniform(1, 10, (g.n, m.P))
        res, status, _ = pc.plan(g, comp, m)
        assert status == "full"
        _assert_bit_identical(res, ceft_jax_csr(g, comp, m))
        graphs.append((g, comp))
    # departures: back to the first DAG — same plane, pure hit
    g0, comp0 = graphs[0]
    res, status, _ = pc.plan(g0, comp0, m)
    assert status == "hit"
    _assert_bit_identical(res, ceft_jax_csr(g0, comp0, m))
    assert len(pc) == 3


def test_lru_eviction_marks_evicted_entry_dirty():
    g = from_edges(4, [(0, 2, 1.0), (1, 2, 2.0), (2, 3, 1.0)])
    m = _machine(2)
    comp = np.asarray([[2.0, 3.0], [1.0, 4.0], [3.0, 2.0], [2.0, 2.0]])
    pc = PlanCache(capacity=2)
    _, _, e0 = pc.plan(g, comp, m, slot="a", classes=[(8, 4)])
    _, _, e1 = pc.plan(g, comp * 2, m, slot="b", classes=[(8, 4)])
    assert not e0.dirty
    pc.plan(g, comp * 3, m, slot="c")
    assert len(pc) == 2
    assert e0.dirty, "evicted entry must be flagged so holders replan"
    assert not e1.dirty
    # eviction also unindexed slot "a": a class invalidation flips only e1
    assert pc.invalidate(wclass=(8, 4)) == 1
    assert e1.dirty


def test_reverse_index_scopes_invalidation_to_workload_class():
    rng = np.random.default_rng(4)
    g, _ = _layered_graph(rng)
    m = _machine()
    comp = rng.uniform(1, 10, (g.n, m.P))
    pc = PlanCache()
    _, _, ea = pc.plan(g, comp, m, slot="a", classes=[(8, 4), (16, 4)])
    _, _, eb = pc.plan(g, comp * 2, m, slot="b", classes=[(32, 4)])
    assert pc.invalidate(wclass=(16, 4)) == 1
    assert ea.dirty and not eb.dirty
    assert pc.invalidate(wclass=(16, 4)) == 0  # already dirty: no new flips
    assert pc.invalidate(wclass=(99, 9)) == 0  # unknown class: touches nothing
    # an engine (straggler) delta rescales a whole comp column: dirty all
    assert pc.invalidate(engine=1) == 1
    assert eb.dirty
    # a byte-equal plane clears the advisory flag on its entry (hit)
    _, status, ea2 = pc.plan(g, comp, m, slot="a")
    assert status == "hit" and ea2 is ea and not ea.dirty


def test_partial_resume_reuses_jit_trace_grid():
    """ISSUE 6 satellite: dirty-frontier resumes must ride the existing
    _geo_bucket shape grid — re-sweeping with deltas at varied depths may
    not mint new jit traces."""
    rng = np.random.default_rng(5)
    g, starts = _layered_graph(rng)
    m = _machine()
    comp = rng.uniform(1, 10, (g.n, m.P))
    pc = PlanCache()
    pc.plan(g, comp, m)          # warm: full sweep traces this shape grid
    comp1 = comp.copy()
    comp1[g.n - 1] *= 1.5
    pc.plan(g, comp1, m)         # warm: one partial (cont-call traces)
    before = set(CSR_TRACES)
    for depth in (6, 10, 12, 16):  # resumes at several distinct runs/depths
        comp = comp.copy()
        comp[int(starts[depth])] *= float(rng.uniform(1.1, 2.0))
        _, status, _ = pc.plan(g, comp, m)
        assert status == "partial"
    assert set(CSR_TRACES) == before, (
        f"partial resumes minted new traces: {set(CSR_TRACES) - before}")


def test_concurrent_plan_and_invalidate_keeps_cache_coherent():
    """ISSUE 6 satellite: worker threads calling plan() on alternating cost
    planes while another thread hammers invalidate() must never serve a
    stale plan or tear the reverse index."""
    rng = np.random.default_rng(6)
    g, _ = _layered_graph(rng)
    m = _machine()
    planes = [rng.uniform(1, 10, (g.n, m.P)) for _ in range(2)]
    refs = [ceft_jax_csr(g, p, m) for p in planes]
    pc = PlanCache()
    errors: list = []
    stop = threading.Event()

    def planner(i):
        try:
            for it in range(12):
                p = planes[(i + it) % 2]
                res, _status, _ = pc.plan(
                    g, p, m, slot=None, classes=[(8, 4)])
                _assert_bit_identical(res, refs[(i + it) % 2])
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append(e)

    def chaos():
        while not stop.is_set():
            pc.invalidate(wclass=(8, 4))
            pc.invalidate(engine=0)

    threads = [threading.Thread(target=planner, args=(i,)) for i in range(2)]
    tc = threading.Thread(target=chaos)
    tc.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    tc.join()
    assert not errors, errors
    snap = pc.snapshot()
    assert snap["hits"] + snap["full_sweeps"] + snap["partial_sweeps"] == 24
    # reverse index only references live plan keys
    with pc._lock:
        for keys in pc._by_class.values():
            assert keys <= set(pc._plans)


def test_graph_store_returns_same_object_for_equal_arrays():
    src = np.asarray([0, 1, 2], np.int32)
    dst = np.asarray([2, 2, 3], np.int32)
    data = np.asarray([1.0, 2.0, 1.0])
    g1 = PC.graph_for(4, src, dst, data)
    g2 = PC.graph_for(4, src.copy(), dst.copy(), data.copy())
    assert g1 is g2
    # and identity-keyed device state is shared too
    r1 = PC.device_state(g1)
    r2 = PC.device_state(g2)
    assert r1[0] is r2[0]


def test_store_false_pass_is_transient_and_cannot_poison_cache():
    """ISSUE 8: a speculative pricing pass (the router's hedge re-plan) with
    store=False returns a correct fresh result but never evicts or
    overwrites the cached entry the steady-state ticks are served from."""
    rng = np.random.default_rng(9)
    g, _ = _layered_graph(rng)
    m = _machine()
    comp = rng.uniform(1, 10, (g.n, m.P))
    pc = PlanCache()
    res0, status0, entry0 = pc.plan(g, comp, m, slot="router")
    assert status0 == "full"
    # transient pass with a DIFFERENT plane into the same slot key
    hedged = comp.copy()
    hedged[:, 0] *= 1e6                      # price class 0 as lost
    res1, _, entry1 = pc.plan(g, hedged, m, slot="router", store=False)
    _assert_bit_identical(res1, ceft_jax_csr(g, hedged, m))
    assert entry1 is not entry0
    # the cached entry is untouched: the original plane still HITS
    res2, status2, entry2 = pc.plan(g, comp, m, slot="router")
    assert status2 == "hit" and entry2 is entry0
    _assert_bit_identical(res2, res0)
