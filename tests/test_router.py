"""CEFT-routed serving front-end (ISSUE 5 + 6): admission queue semantics,
deterministic dispatch on fake engines, dispatch decisions driven by the
plan cache (sweep-count + bit-identity to the unbatched dense reference on
the router's own request DAGs), steady-state cache-hit ticks, and
straggler-driven critical-path shedding."""
import numpy as np
import pytest

from repro.core import ceft
from repro.core.ceft_jax import (
    CSR_TRACES,
    ceft_jax,
    plan_request_dag,
    plan_request_dags,
    request_graph,
)
from repro.sched import plancache as PC
from repro.serve import (
    AdmissionQueue,
    Dispatch,
    EngineSlot,
    Request,
    Router,
    workload_class,
)


class FakeEngine:
    """Pool member that records calls and returns deterministic tokens."""

    def __init__(self):
        self.calls: list[tuple[int, int, int]] = []

    def generate(self, prompts, scfg):
        B, P = prompts.shape
        self.calls.append((B, P, scfg.max_new_tokens))
        return np.full((B, P + scfg.max_new_tokens), 7, np.int32)


def _mk_router(P=2, **kw):
    slots = [EngineSlot(f"e{i}", FakeEngine(), "baseline") for i in range(P)]
    return Router(slots, **kw), slots


def _submit_mixed(router, rng, per_class=4, classes=(8, 16), max_new=4):
    for t, plen in enumerate(classes):
        for _ in range(per_class):
            prompt = rng.integers(2, 100, plen).astype(np.int32)
            assert router.submit(Request(f"t{t}", prompt, max_new))


# ------------------------------------------------------------------- queue
def test_workload_class_buckets_pow2():
    assert workload_class(1, 1) == (1, 1)
    assert workload_class(8, 4) == (8, 4)
    assert workload_class(9, 5) == (16, 8)


def test_admission_queue_bounds_and_fairness():
    q = AdmissionQueue(max_pending=6, per_tenant=3)
    reqs = {t: [Request(t, np.zeros(4, np.int32), 2) for _ in range(4)]
            for t in ("a", "b")}
    admitted = [q.submit(r) for t in ("a", "b") for r in reqs[t]]
    # per-tenant cap = 3: the 4th of each tenant is rejected
    assert admitted == [True] * 3 + [False] + [True] * 3 + [False]
    assert q.rejected == 2 and len(q) == 6
    drained = q.drain()
    # round-robin interleave: a, b, a, b, ... not a's backlog first
    assert [r.tenant for r in drained] == ["a", "b", "a", "b", "a", "b"]
    assert len(q) == 0 and q.drain() == []


def test_admission_queue_global_bound():
    q = AdmissionQueue(max_pending=2, per_tenant=64)
    assert q.submit(Request("a", np.zeros(2, np.int32), 1))
    assert q.submit(Request("b", np.zeros(2, np.int32), 1))
    assert not q.submit(Request("c", np.zeros(2, np.int32), 1))
    assert len(q.drain(limit=1)) == 1 and len(q) == 1


# ----------------------------------------------------------- deterministic smoke
def test_router_smoke_deterministic():
    """Same submissions -> identical dispatch decisions, every request served
    exactly once, outputs shaped per request."""
    seqs = []
    for _ in range(2):
        router, slots = _mk_router(P=2)
        rng = np.random.default_rng(0)
        _submit_mixed(router, rng)
        dispatches = router.tick()
        seqs.append([(d.engine, d.wclass, len(d.requests), d.on_critical_path)
                     for d in dispatches])
        done = {}
        for d in dispatches:
            done.update(router.run_dispatch(d))
        assert len(done) == 8        # every request served exactly once
        for d in dispatches:
            for r in d.requests:
                assert done[r.rid].shape[0] >= r.prompt.shape[0] + 1
        # both engines got work (load-aware EFT, not all-on-engine-0)
        used = {d.engine for d in dispatches}
        assert used == {0, 1}
    assert seqs[0] == seqs[1]


# ------------------------------------------- CSR-driven dispatch + bit-identity
def test_dispatch_decisions_driven_by_csr_sweeps():
    """Acceptance (ISSUE 6): every dispatch descends from a plan-cache sweep
    -- one full sweep for the first mix, steady-state repeats served from
    cache with ZERO sweeps, critical-path dispatches follow the plan's own
    task->engine mapping, cost deltas invalidate and force a replan, and
    repeated same-shape ticks stay inside the already-compiled trace set."""
    router, _ = _mk_router(P=2)
    rng = np.random.default_rng(1)
    _submit_mixed(router, rng)
    first = router.tick()
    assert router.stats["plans"] == 1
    assert router.plancache.counters["full_sweeps"] >= 1
    assert first, "non-empty queue must produce dispatches"
    res = router.last_plan
    for d in first:
        if d.on_critical_path:
            assert d.engine == res.assignment.get(
                d.node_decode, res.assignment.get(d.node_prefill))
    # empty tick: no plan, no dispatch
    assert router.tick() == [] and router.stats["plans"] == 1
    # steady state: same-mix ticks are cache hits -- no sweeps, no compiles
    traces_before = dict(CSR_TRACES)
    sweeps_before = router.plancache.snapshot()
    for k in range(1, 4):
        _submit_mixed(router, rng)
        assert router.tick(), "same mix must still dispatch"
        assert router.stats["cache_hits"] == k
    sweeps_after = router.plancache.snapshot()
    assert sweeps_after["full_sweeps"] == sweeps_before["full_sweeps"]
    assert sweeps_after["partial_sweeps"] == sweeps_before["partial_sweeps"]
    assert set(CSR_TRACES) == set(traces_before), \
        "steady-state router ticks must not compile new traces"
    # a measured cost delta dirties the cached plan via the reverse index:
    # the very next tick must replan instead of serving the stale schedule
    router.observe(0, (8, 4), 0.004, 100)
    assert router.stats["invalidations"] >= 1
    _submit_mixed(router, rng)
    router.tick()
    assert router.stats["plans"] == 2


def test_router_dag_plan_bit_identical_to_unbatched_reference():
    """Acceptance: on the router's own request DAGs the CSR plan is
    bit-identical to the unbatched dense sweep (values, predecessors, path)
    and the batched form matches the unbatched CSR form."""
    router, _ = _mk_router(P=3)
    rng = np.random.default_rng(2)
    # heterogeneous observed rates -> non-trivial comp planes
    for wc in ((8, 4), (16, 4), (32, 4)):
        for e in range(3):
            router.costs.update(wc, e, float(rng.uniform(0.5e-3, 3e-3)))
    _submit_mixed(router, rng, per_class=3, classes=(8, 16, 32))
    router.tick()
    n, src, dst, data, comp = router.last_dag
    res_csr = plan_request_dag(n, src, dst, data, comp, router.machine)
    ref = ceft_jax(request_graph(n, src, dst, data), comp, router.machine)
    assert np.array_equal(res_csr.ceft, ref.ceft)
    assert np.array_equal(res_csr.pred_task, ref.pred_task)
    assert np.array_equal(res_csr.pred_proc, ref.pred_proc)
    assert res_csr.path == ref.path and res_csr.cpl == ref.cpl
    # batched (the degraded-scenario form) == unbatched CSR, plane by plane
    m = router.machine
    planes = np.stack([comp, comp * 1.7])
    Ls = np.repeat(np.asarray(m.L, np.float32)[None], 2, 0)
    bws = np.repeat(np.asarray(m.bw, np.float32)[None], 2, 0)
    batched = plan_request_dags(n, src, dst, data, planes, Ls, bws)
    for b, plane in enumerate(planes):
        single = plan_request_dag(n, src, dst, data, plane, m)
        assert np.array_equal(batched[b].ceft, single.ceft)
        assert batched[b].path == single.path
    # and the float64 numpy CEFT agrees on path + cpl
    f64 = ceft(request_graph(n, src, dst, data), comp, router.machine)
    assert f64.path == res_csr.path
    assert res_csr.cpl == pytest.approx(f64.cpl, rel=1e-5)


def test_request_graph_content_store():
    """Structurally-equal edge arrays -> the SAME TaskGraph object (the plan
    cache's content-keyed graph store), so the identity-keyed device-state
    store (fused segment tables) hits across ticks."""
    src = np.asarray([0, 1], np.int32)
    dst = np.asarray([2, 3], np.int32)
    data = np.asarray([8.0, 16.0])
    g1 = request_graph(4, src, dst, data)
    g2 = request_graph(4, src.copy(), dst.copy(), data.copy())
    assert g1 is g2
    comp = np.ones((4, 2))
    plan_request_dag(4, src, dst, data, comp, _mk_router(P=2)[0].machine)
    assert id(g1) in PC._DEVICE_STATE, \
        "request-DAG planning must populate the device-state store"
    # different structure -> different graph (no false sharing)
    g3 = request_graph(4, src, dst, np.asarray([8.0, 17.0]))
    assert g3 is not g1


# ------------------------------------------------------------- straggler tie-in
def test_degraded_engine_sheds_critical_path_work():
    """Feeding StragglerMonitor observations back into the cost table moves
    the planned critical path off the degraded engine (nominal + degraded
    scenario planes through the plan cache's slots)."""
    router, slots = _mk_router(P=2)
    rng = np.random.default_rng(3)
    # engine 0 measured consistently faster: the path lands on engine 0
    for wc in ((8, 4), (16, 4)):
        router.costs.update(wc, 0, 1e-3)
        router.costs.update(wc, 1, 2e-3)
    _submit_mixed(router, rng)
    router.tick()
    assert set(dict(router.last_plan.path).values()) == {0}
    assert router.stats["degraded_plans"] == 0

    # healthy baseline, then engine 0 degrades 5x past the monitor threshold;
    # the slowdown deltas must dirty the cached plan (engine-scope
    # invalidation) so the degraded tick cannot serve the stale schedule
    router.observe_step(np.asarray([1.0, 1.0]))
    for _ in range(10):
        router.observe_step(np.asarray([5.0, 1.0]))
    assert router._slow[0] >= router.monitor.threshold
    assert router.stats["invalidations"] >= 1
    _submit_mixed(router, rng)
    dispatches = router.tick()
    assert router.stats["degraded_plans"] == 1    # nominal + degraded planes
    assert router.stats["shed"] > 0               # path moved off engine 0
    assert set(dict(router.last_plan.path).values()) == {1}
    assert set(dict(router.last_nominal.path).values()) == {0}
    for d in dispatches:
        if d.on_critical_path:
            assert d.engine == 1


def test_latency_bound_splits_oversized_microbatches():
    """Coalescing is bounded by the CEFT path length: a class whose batch
    would exceed the bound splits, one whose batch fits coalesces."""
    router, _ = _mk_router(P=2, max_batch=64, latency_slack=1.0)
    rng = np.random.default_rng(4)
    # class (8,4) is 40x cheaper per token on both engines than (16,4):
    # the (16,4) chain is the critical path, and the cheap class's requests
    # all fit under it; shrink latency_slack to force a split instead
    for e in range(2):
        router.costs.update((8, 4), e, 1e-4)
        router.costs.update((16, 4), e, 4e-3)
    _submit_mixed(router, rng, per_class=8)
    dispatches = router.tick()
    cheap = [d for d in dispatches if d.wclass == (8, 4)]
    assert len(cheap) == 1 and len(cheap[0].requests) == 8   # coalesced
    assert router.stats["coalesced"] >= 7

    router2, _ = _mk_router(P=2, max_batch=64, latency_slack=0.01)
    for e in range(2):
        router2.costs.update((8, 4), e, 1e-4)
        router2.costs.update((16, 4), e, 4e-3)
    _submit_mixed(router2, rng, per_class=8)
    dispatches2 = router2.tick()
    cheap2 = [d for d in dispatches2 if d.wclass == (8, 4)]
    assert len(cheap2) > 1                                   # bound forced a split
    assert router2.stats["split"] >= 1


def test_microbatches_never_mix_prompt_lengths():
    """Engines have no padding mask: requests sharing a workload class but
    differing in exact prompt length must land in separate micro-batches
    (a mixed batch would condition the shorter prompts on filler tokens)."""
    router, _ = _mk_router(P=2)
    rng = np.random.default_rng(6)
    for plen in (9, 12, 16):        # all bucket to workload class (16, 4)
        assert router.submit(
            Request("t0", rng.integers(2, 100, plen).astype(np.int32), 4))
    dispatches = router.tick()
    assert {d.wclass for d in dispatches} == {(16, 4)}
    assert len(dispatches) == 3     # one per exact length
    for d in dispatches:
        assert len({int(r.prompt.shape[0]) for r in d.requests}) == 1
        router.run_dispatch(d)      # executes cleanly
    # a hand-built mixed batch is rejected loudly instead of padding
    bad = Dispatch(engine=0, requests=[
        Request("t0", np.full(9, 3, np.int32), 4),
        Request("t0", np.full(16, 3, np.int32), 4)],
        wclass=(16, 4), on_critical_path=False, node_prefill=0, node_decode=1)
    with pytest.raises(ValueError, match="mixes prompt lengths"):
        router.run_dispatch(bad)


def test_steady_state_ticks_hit_request_graph_cache():
    """Bucketed DAG volumes: ticks with the same class mix + counts but
    different exact prompt lengths produce byte-identical DAGs, so the
    whole second tick is a plan-cache hit (no per-tick segment rebuild, no
    sweep)."""
    router, _ = _mk_router(P=2)
    rng = np.random.default_rng(7)
    for plen in (9, 11):            # tick 1: two requests in class (16, 4)
        router.submit(Request("t0", rng.integers(2, 100, plen).astype(np.int32), 4))
    router.tick()
    g1 = request_graph(*router.last_dag[:4])
    sweeps = router.plancache.snapshot()["full_sweeps"]
    for plen in (13, 16):           # tick 2: same mix, different exact lens
        router.submit(Request("t0", rng.integers(2, 100, plen).astype(np.int32), 4))
    router.tick()
    assert request_graph(*router.last_dag[:4]) is g1
    assert router.stats["cache_hits"] == 1
    assert router.plancache.snapshot()["full_sweeps"] == sweeps


def test_tick_budget_bounds_dispatches_and_keeps_residents():
    """Incremental admission: a bounded tick dispatches at most tick_budget
    requests (split round-robin across classes), the remainder stays
    resident, and steady-state refills at the same mix are cache hits."""
    router, _ = _mk_router(P=2, tick_budget=2)
    rng = np.random.default_rng(9)
    _submit_mixed(router, rng, per_class=4)          # 8 requests, 2 classes
    d1 = router.tick()
    assert sum(len(d.requests) for d in d1) == 2
    # round-robin split: one from each class, not two from the first
    assert sorted(d.wclass for d in d1) == [(8, 4), (16, 4)]
    assert router.stats["resident"] == 6
    # refill exactly what left: the mix signature is restored -> cache hit
    _submit_mixed(router, rng, per_class=1)
    d2 = router.tick()
    assert sum(len(d.requests) for d in d2) == 2
    assert router.stats["cache_hits"] >= 1
    # drain the rest without refills: counts shrink, mix changes, replans
    served = 4
    for _ in range(8):
        served += sum(len(d.requests) for d in router.tick())
        if not router.resident:
            break
    assert served == 10 and not router.resident


def test_admission_queue_drops_empty_tenants():
    """Ephemeral tenants must not leak dict entries after drain."""
    q = AdmissionQueue()
    for t in range(50):
        q.submit(Request(f"ephemeral{t}", np.zeros(4, np.int32), 2))
    assert len(q.drain()) == 50
    assert len(q._pending) == 0


def test_serve_runs_engines_in_parallel():
    """serve() executes each engine's micro-batches on its own worker thread
    (the CEFT makespan assumes parallel processor classes)."""
    import threading as th

    barrier = th.Barrier(2, timeout=30)

    class MeetingEngine:
        def generate(self, prompts, scfg):
            barrier.wait()  # deadlocks unless both engines run concurrently
            B, P = prompts.shape
            return np.zeros((B, P + scfg.max_new_tokens), np.int32)

    slots = [EngineSlot(f"e{i}", MeetingEngine(), "baseline") for i in range(2)]
    router = Router(slots)
    # separate classes with rates steering one class per engine
    router.costs.update((8, 4), 0, 1e-3)
    router.costs.update((8, 4), 1, 2e-3)
    router.costs.update((16, 4), 0, 2e-3)
    router.costs.update((16, 4), 1, 1e-3)
    rng = np.random.default_rng(8)
    _submit_mixed(router, rng, per_class=2)
    done = router.serve(max_ticks=1)
    assert len(done) == 4


def test_serve_surfaces_engine_failures():
    """A dying engine must fail serve() loudly, not silently return a
    partial result dict (which would pass smoke runs)."""
    class DeadEngine:
        def generate(self, prompts, scfg):
            raise RuntimeError("engine down")

    router = Router([EngineSlot("e0", DeadEngine(), "baseline")])
    router.submit(Request("t0", np.full(8, 3, np.int32), 2))
    with pytest.raises(RuntimeError, match="engine down"):
        router.serve(max_ticks=1)


def test_serve_aggregates_concurrent_engine_failures():
    """Two engines dying in the SAME tick must BOTH surface: the old serve()
    raised only errors[0], silently dropping every concurrent failure."""
    class DeadEngine:
        def __init__(self, msg):
            self.msg = msg

        def generate(self, prompts, scfg):
            raise RuntimeError(self.msg)

    slots = [EngineSlot(f"e{i}", DeadEngine(f"boom-{i}"), "baseline")
             for i in range(2)]
    router = Router(slots)
    # rates steering one class to each engine, so both threads run and fail
    router.costs.update((8, 4), 0, 1e-3)
    router.costs.update((8, 4), 1, 2e-3)
    router.costs.update((16, 4), 0, 2e-3)
    router.costs.update((16, 4), 1, 1e-3)
    rng = np.random.default_rng(10)
    _submit_mixed(router, rng, per_class=2)
    with pytest.raises(RuntimeError) as exc_info:
        router.serve(max_ticks=1)
    err = exc_info.value
    assert "2 engines failed concurrently" in str(err)
    assert "boom-0" in str(err) and "boom-1" in str(err)
    assert "e0" in str(err) and "e1" in str(err)
    # the original exceptions ride along with per-engine context
    assert {name for name, _ in err.failures} == {"e0", "e1"}
    assert all(isinstance(e, RuntimeError) for _, e in err.failures)


def test_serve_keeps_survivors_when_pool_worker_dies_mid_tick():
    """A pool worker DEATH mid-tick is degradation, not an abort (ISSUE 7):
    the survivors' results from the same tick are kept, the loss lands in
    ``router.failures`` with per-engine context instead of raising, and the
    next tick's plan routes the dead worker's requeued work around it."""
    from repro.serve import WorkerLost

    class CrashingEngine:
        def generate(self, prompts, scfg):
            raise WorkerLost("e1", 1, "SIGKILL mid-tick")

    slots = [EngineSlot("e0", FakeEngine(), "baseline"),
             EngineSlot("e1", CrashingEngine(), "baseline")]
    router = Router(slots)
    # rates steering one class to each engine, so both threads run this tick
    router.costs.update((8, 4), 0, 1e-3)
    router.costs.update((8, 4), 1, 2e-3)
    router.costs.update((16, 4), 0, 2e-3)
    router.costs.update((16, 4), 1, 1e-3)
    rng = np.random.default_rng(21)
    _submit_mixed(router, rng, per_class=2)
    done = router.serve()                       # must NOT raise
    assert len(done) == 4, "survivor results kept, lost work re-served"
    assert slots[0].engine.calls, "survivor actually ran"
    (name, err), = router.failures
    assert name == "e1" and isinstance(err, WorkerLost)
    assert err.index == 1 and "SIGKILL" in err.cause
    assert router.pool.state(1) == "lost"
    # the re-planned ticks mapped everything onto the survivor
    assert set(dict(router.last_plan.path).values()) == {0}
    assert router.stats["requeued"] > 0
    assert router.stats["degraded_plans"] >= 1


def test_run_dispatch_trims_rows_to_request_budget():
    """Coalesced requests with different max_new: each returned row is cut to
    its own prompt+max_new budget, not the batch maximum."""
    router, _ = _mk_router(P=1)
    r1 = Request("t0", np.full(8, 3, np.int32), 3)
    r2 = Request("t0", np.full(8, 3, np.int32), 4)   # same class (8, 4)
    assert r1.wclass == r2.wclass
    router.submit(r1)
    router.submit(r2)
    (d,) = router.tick()
    out = router.run_dispatch(d)
    assert out[r1.rid].shape[0] == 8 + 3
    assert out[r2.rid].shape[0] == 8 + 4


def test_rejected_submit_leaks_no_tenant_entry():
    q = AdmissionQueue(max_pending=1, per_tenant=1)
    assert q.submit(Request("a", np.zeros(2, np.int32), 1))
    for t in range(20):
        assert not q.submit(Request(f"flood{t}", np.zeros(2, np.int32), 1))
    assert list(q._pending) == ["a"] and q.rejected == 20


# ------------------------------------------------------- SLO plane (ISSUE 9)
def test_zero_weight_tier_rejected_at_construction():
    from repro.serve import TenantTier

    with pytest.raises(ValueError, match="starve"):
        TenantTier("bad", 0.0)
    with pytest.raises(ValueError):
        TenantTier("bad", -1.0)
    with pytest.raises(ValueError):
        TenantTier("bad", float("inf"))
    with pytest.raises(ValueError, match="slo"):
        TenantTier("bad", 1.0, slo=0.0)
    with pytest.raises(TypeError, match="TenantTier"):
        AdmissionQueue(tiers={"a": 2.0})


def test_weighted_drain_bounds_starvation_under_flood():
    """Fairness acceptance: 8 flooding low-weight tenants, one weight-8 vip
    submitting LAST — the vip is popped within its starvation bound, and no
    low tenant ever waits more than ITS bound between pops either (the
    weighted drain trades position, never liveness)."""
    from repro.serve import TenantTier

    tiers = {f"low{t}": TenantTier(f"low{t}", 1.0) for t in range(8)}
    tiers["vip"] = TenantTier("vip", 8.0)
    q = AdmissionQueue(max_pending=512, per_tenant=64, tiers=tiers)
    for t in range(8):
        for _ in range(8):
            assert q.submit(Request(f"low{t}", np.zeros(4, np.int32), 2))
    for _ in range(8):
        assert q.submit(Request("vip", np.zeros(4, np.int32), 2))
    b_vip = q.starvation_bound("vip")
    b_low = q.starvation_bound("low0")
    assert b_vip == 4                              # ceil(2 x 16 / 8)
    assert b_low == 32                             # ceil(2 x 16 / 1)
    order = [r.tenant for r in q.drain()]
    assert len(order) == 72
    vip_pos = [i for i, t in enumerate(order) if t == "vip"]
    # first pop within the bound despite submitting behind the whole flood,
    # then at most bound slots between consecutive pops — and the weighting
    # actually bites: vip holds ~half the slots while its backlog lasts
    assert vip_pos[0] < b_vip
    assert all(b - a <= b_vip for a, b in zip(vip_pos, vip_pos[1:]))
    assert vip_pos[-1] < 16, "weight-8 vip must drain inside the first period"
    for t in range(8):
        pos = [i for i, x in enumerate(order) if x == f"low{t}"]
        assert pos[0] < b_low
        assert all(b - a <= b_low for a, b in zip(pos, pos[1:]))


def test_tier_slo_stamped_at_admission():
    from repro.serve import TenantTier

    q = AdmissionQueue(tiers={"gold": TenantTier("gold", 2.0, slo=1.5)})
    r = Request("gold", np.zeros(4, np.int32), 2)
    assert r.slo is None and r.deadline is None
    assert q.submit(r)
    assert r.slo == 1.5 and r.t_submit > 0.0
    assert r.deadline == pytest.approx(r.t_submit + 1.5)
    # a request carrying its own (tighter) slo keeps it
    r2 = Request("gold", np.zeros(4, np.int32), 2, slo=0.25)
    assert q.submit(r2) and r2.slo == 0.25
    # untiered tenants stay best-effort
    r3 = Request("other", np.zeros(4, np.int32), 2)
    assert q.submit(r3)
    assert r3.slo is None and r3.deadline is None


def test_clamped_budget_counted_in_stats():
    """Satellite (ISSUE 9): the 10x slowdown cap in the budget path used to
    be silent — a capped budget under-states a genuinely slower engine's
    span, so hitting the cap must be observable in stats."""
    router, _ = _mk_router(P=2)
    d = Dispatch(engine=1, requests=[Request("t0", np.zeros(8, np.int32), 4)],
                 wclass=(8, 4), on_critical_path=False,
                 node_prefill=0, node_decode=1)
    router._slow = np.array([1.0, 50.0])
    span_capped = router.planned_span(d)
    assert router.stats["clamped_budgets"] == 1
    # the span is priced AT the cap: 50x and 10x give the same number
    router._slow = np.array([1.0, 10.0])
    assert router.planned_span(d) == pytest.approx(span_capped)
    assert router.stats["clamped_budgets"] == 1    # 10x is the cap, not past it
    router._slow = np.array([1.0, 9.0])
    router.planned_span(d)
    assert router.stats["clamped_budgets"] == 1


def test_overdue_ladder_is_slack_keyed():
    """Strike 1 keyed on the dispatch's remaining SLO budget: slack-rich
    work sheds (requeued immediately, exactly once), SLO-critical off-path
    work hedges like critical-path work, best-effort work keeps the
    historical wait-for-strike-2 ladder."""
    import time as _time

    router, _ = _mk_router(P=2, deadline_factor=3.0, min_deadline=0.05)
    wd = router.watchdog
    now = _time.monotonic()

    def mk(deadline):
        return Dispatch(
            engine=0, requests=[Request("t0", np.full(8, 3, np.int32), 4)],
            wclass=(8, 4), on_critical_path=False, node_prefill=0,
            node_decode=1, deadline=deadline)

    # slack-rich: remaining >= 2 budgets -> shed at strike 1
    d_rich = mk(now + 100.0)
    e = wd.arm(1, d_rich, planned_span=0.01, engine=0, on_critical_path=False,
               budget=1.0)
    e.strikes = 1
    router._on_overdue(e, now)
    assert e.shed and not e.hedged
    assert router.stats["slo_shed"] == 1
    assert router._wd_requeue == [d_rich]
    e.strikes = 2
    router._on_overdue(e, now)             # a strike-1 shed must not requeue twice
    assert router._wd_requeue == [d_rich]
    # SLO-critical off-path: remaining < 1 budget -> hedges like CP work
    d_crit = mk(now + 0.5)
    e2 = wd.arm(2, d_crit, planned_span=0.01, engine=0,
                on_critical_path=False, budget=1.0)
    e2.strikes = 1
    router._on_overdue(e2, now)
    assert e2.hedged and not e2.shed
    assert router.stats["slo_hedges"] == 1 and router.stats["hedges"] == 1
    for t in router._hedge_threads:
        t.join(timeout=10.0)
    # best-effort middling work does neither at strike 1 (historical ladder)
    d_mid = mk(None)
    e3 = wd.arm(3, d_mid, planned_span=0.01, engine=0, on_critical_path=False,
                budget=1.0)
    e3.strikes = 1
    router._on_overdue(e3, now)
    assert not e3.shed and not e3.hedged
    assert router._wd_requeue == [d_rich]


def test_slo_shed_holds_back_slack_rich_work_on_degraded_engine():
    """Tick-time shedding: of the dispatches planned onto a tripped engine,
    the most-slack one is held back for the next tick's re-plan; tight-slack
    work keeps its slot, and with no healthy engine nothing sheds."""
    router, _ = _mk_router(P=2)
    router._slow = np.array([5.0, 1.0])    # engine 0 past the 1.3x threshold

    def mk(eng, slack):
        return Dispatch(
            engine=eng, requests=[Request("t0", np.full(8, 3, np.int32), 4)],
            wclass=(8, 4), on_critical_path=False, node_prefill=0,
            node_decode=1, slack=slack)

    rich, tight, healthy = mk(0, 5.0), mk(0, 0.0), mk(1, 9.0)
    out = router._slo_shed([rich, tight, healthy])
    assert out == [tight, healthy]
    assert router.stats["slo_shed"] == 1
    assert list(router.resident[(8, 4)]) == rich.requests
    # no healthy engine: deferring is pure livelock, so nothing sheds
    router2, _ = _mk_router(P=1)
    router2._slow = np.array([5.0])
    a, b = mk(0, 5.0), mk(0, 6.0)
    assert router2._slo_shed([a, b]) == [a, b]
    assert router2.stats["slo_shed"] == 0


def test_run_dispatch_updates_cost_table():
    router, slots = _mk_router(P=2)
    rng = np.random.default_rng(5)
    _submit_mixed(router, rng, per_class=2, classes=(8,))
    (d,) = router.tick()
    assert router.costs._rows == {}
    router.run_dispatch(d)
    row = router.costs.row(d.wclass)
    assert np.isfinite(row).all() and row[d.engine] > 0
    assert slots[d.engine].engine.calls, "dispatch must hit the planned engine"
