"""Pipeline parallelism: the shard_map GPipe forward equals the scanned
forward bit-for-bit (fp32).  Runs in a subprocess with 4 fake devices."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    import repro.configs as C
    from repro.models.model import build
    from repro.models import transformer
    from repro.launch.pipeline import pipeline_forward
    from repro.substrate import mesh_context
    import dataclasses

    cfg = dataclasses.replace(C.get("granite-3-8b", smoke=True),
                              n_layers=4, compute_dtype="float32", remat="none")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 8, 16
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    # reference: the scanned stack
    hidden_ref, _, _ = transformer.forward_full(params, cfg, tokens=tok)

    # pipeline: embed -> 4-stage GPipe over the blocks -> final norm
    mesh = Mesh(np.array(jax.devices()).reshape(4), ("pipe",))
    x = transformer.embed_tokens(params, cfg, tok)
    from repro.models.layers import rmsnorm
    with mesh_context(mesh):
        h = jax.jit(lambda blocks, x: pipeline_forward(
            cfg, blocks, x, mesh, n_micro=4))(params["blocks"], x)
    hidden_pp = rmsnorm(params["final_norm"], h, cfg.norm_eps)

    err = float(jnp.max(jnp.abs(hidden_pp.astype(jnp.float32)
                                - hidden_ref.astype(jnp.float32))))
    denom = float(jnp.max(jnp.abs(hidden_ref.astype(jnp.float32)))) + 1e-9
    assert err / denom < 1e-5, (err, denom)
    print("PIPELINE_OK", err / denom)
""")


def test_gpipe_forward_matches_scan(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PIPELINE_OK" in r.stdout
