"""End-to-end system tests: fault-tolerant training, elastic restore, the
serving engine, the CEFT pipeline partitioner and straggler re-planning."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import SHAPES, ShapeCell
from repro.launch.mesh import make_test_mesh
from repro.sched import DEFAULT_FLEET, DeviceClass, StragglerMonitor, build_layer_dag, plan_pipeline
from repro.serve import Engine, ServeConfig
from repro.train import Trainer, TrainerConfig

SMOKE_CELL = ShapeCell("smoke", seq_len=32, global_batch=4, kind="train")


def _trainer(tmp_path, arch="minicpm-2b", **kw):
    cfg = C.get(arch, smoke=True)
    tcfg = TrainerConfig(steps=kw.pop("steps", 12), ckpt_every=4,
                         ckpt_dir=str(tmp_path), log_every=1, **kw)
    return Trainer(cfg, SMOKE_CELL, tcfg, make_test_mesh)


def test_train_loss_decreases(tmp_path):
    tr = _trainer(tmp_path, steps=15)
    metrics = [m for m in tr.run() if "loss" in m]
    first = np.mean([m["loss"] for m in metrics[:3]])
    last = np.mean([m["loss"] for m in metrics[-3:]])
    assert last < first, (first, last)


def test_failure_recovery_resumes_from_checkpoint(tmp_path):
    """A simulated node loss at step 7 restarts from the step-4 checkpoint and
    still completes all steps; the restart event is logged."""
    tr = _trainer(tmp_path, steps=10, fail_at_steps=(7,))
    metrics = tr.run()
    events = [m for m in metrics if "event" in m and "restart" in str(m["event"])]
    assert len(events) == 1
    steps_logged = [m["step"] for m in metrics if "loss" in m]
    assert max(steps_logged) == 10
    assert tr.restarts == 1


def test_recovery_reproduces_unfailed_run(tmp_path):
    """Determinism: a run with a mid-flight failure converges to the same
    final loss trajectory as an unfailed run (same data stream + restore)."""
    a = _trainer(tmp_path / "a", steps=8)
    ma = [m for m in a.run() if "loss" in m]
    b = _trainer(tmp_path / "b", steps=8, fail_at_steps=(6,))
    mb = [m for m in b.run() if "loss" in m]
    la = {m["step"]: m["loss"] for m in ma}
    lb = {m["step"]: m["loss"] for m in mb}
    for s in (7, 8):
        assert la[s] == pytest.approx(lb[s], rel=2e-4), s


def test_straggler_replan_event(tmp_path):
    """A sustained slowdown of one device class trips the EWMA monitor and
    produces a CEFT-CPOP re-plan whose makespan reflects the degradation."""
    tr = _trainer(tmp_path, steps=8,
                  straggler_sim={6: (0, 2.5), 7: (0, 2.5), 8: (0, 2.5)})
    metrics = tr.run()
    ev = [m for m in metrics if m.get("event") == "straggler_replan"]
    assert ev, "no straggler event fired"
    assert ev[0]["slowdown"] >= 1.3 - 1e-6


def test_engine_generates_and_stops_on_eos():
    cfg = C.get("granite-3-8b", smoke=True)
    eng = Engine(cfg)
    prompts = np.asarray(np.random.default_rng(0).integers(2, cfg.vocab, (3, 8)),
                         np.int32)
    out = eng.generate(prompts, ServeConfig(max_new_tokens=8, eos_id=1))
    assert out.shape == (3, 16)
    np.testing.assert_array_equal(out[:, :8], prompts)


def test_engine_swa_ring_cache():
    """Generation also works when the prompt exceeds the SWA window (ring
    packing path)."""
    cfg = dataclasses.replace(C.get("mixtral-8x22b", smoke=True), window=8)
    eng = Engine(cfg)
    prompts = np.asarray(np.random.default_rng(0).integers(2, cfg.vocab, (2, 12)),
                         np.int32)
    out = eng.generate(prompts, ServeConfig(max_new_tokens=4, eos_id=1))
    assert out.shape == (2, 16)


def test_engine_ssm_state_cache():
    cfg = C.get("mamba2-2.7b", smoke=True)
    eng = Engine(cfg)
    prompts = np.asarray(np.random.default_rng(0).integers(2, cfg.vocab, (2, 8)),
                         np.int32)
    out = eng.generate(prompts, ServeConfig(max_new_tokens=4, eos_id=1))
    assert out.shape == (2, 12)


# ------------------------------------------------------------------ scheduler
def test_layer_dag_structure():
    g, comp, m, labels = build_layer_dag(C.get("glm4-9b"), SHAPES["train_4k"],
                                         n_micro=4)
    S = C.get("glm4-9b").n_layers + 2
    assert g.n == 2 * 4 * S  # fwd + bwd grids
    assert comp.shape == (g.n, m.P)
    assert (comp > 0).all()
    assert g.n_edges == 4 * (S - 1) + 4 * S + 4 * (S - 1)


@pytest.mark.parametrize("arch", ["llama3-405b", "jamba-v0.1-52b", "mamba2-2.7b"])
def test_partitioner_plans_are_valid_and_bounded(arch):
    plan = plan_pipeline(C.get(arch), SHAPES["train_4k"])
    assert plan.cpl > 0
    assert plan.makespan >= plan.cpl * 0.999
    assert plan.makespan <= plan.makespan_cpop * 1.001  # never worse than CPOP
    assert len(plan.stages) >= 1


def test_partitioner_prefers_bandwidth_class_for_decode():
    """Decode stages are bandwidth-bound: the plan lands on the
    bandwidth-rich class; training lands on the flops-rich class."""
    train = plan_pipeline(C.get("glm4-9b"), SHAPES["train_4k"])
    dec = plan_pipeline(C.get("glm4-9b"), SHAPES["decode_32k"])
    assert {s.device_class for s in train.stages} == {"v5e-96"}
    assert {s.device_class for s in dec.stages} == {"v5p-32"}


def test_straggler_below_threshold_returns_warm_nominal():
    """Regression (ISSUE 5): below threshold maybe_replan returned
    (None, None) and never warmed the nominal cache, despite the docstring's
    'otherwise schedules with nominal costs' -- the first straggler event
    then paid for both sweeps.  It must return the cached nominal schedule
    (computed on first call) and later events must reuse it.  Sweep counts
    now come from the unified plan cache's counters (ISSUE 6)."""
    from repro.core import from_edges, uniform_machine

    g = from_edges(4, [(0, 2, 1.0), (1, 2, 2.0), (2, 3, 1.0)])
    comp = np.asarray([[2.0, 3.0], [1.0, 4.0], [3.0, 2.0], [2.0, 2.0]])
    m = uniform_machine(2, bw=1.0, L=0.1)

    mon = StragglerMonitor(2, threshold=1.3)
    sched0, ev0 = mon.maybe_replan(1, g, comp, m, np.ones(2))
    assert ev0 is None
    assert sched0 is not None and sched0.makespan > 0
    c = mon.plancache.snapshot()
    assert c["full_sweeps"] == 1 and c["hits"] == 0   # one nominal sweep
    # second quiet step: cache hit, same schedule object, no new sweep
    sched1, ev1 = mon.maybe_replan(2, g, comp, m, np.ones(2))
    assert sched1 is sched0 and ev1 is None
    c = mon.plancache.snapshot()
    assert c["full_sweeps"] == 1 and c["hits"] == 1
    # a straggler event reuses the warmed nominal: degraded sweep only
    times = np.asarray([3.0, 1.0])
    sched2, ev2 = mon.maybe_replan(3, g, comp, m, times)
    assert ev2 is not None
    c = mon.plancache.snapshot()
    assert c["full_sweeps"] == 2, \
        "warm nominal cache must not re-sweep the baseline"
    assert c["hits"] == 2       # the event's nominal lookup is a pure hit
    assert ev2.old_makespan == sched0.makespan


def test_straggler_nominal_cache_hits_on_rebuilt_equal_inputs():
    """Regression (ISSUE 4): the nominal-baseline cache used to key the graph
    by object identity — a re-built but equal (graph, comp, machine) triple
    recomputed the baseline.  Content-hash keys must hit the cache."""
    from repro.core import from_edges, uniform_machine

    edges = [(0, 2, 1.0), (1, 2, 2.0), (2, 3, 1.0)]
    comp = np.asarray([[2.0, 3.0], [1.0, 4.0], [3.0, 2.0], [2.0, 2.0]])
    m = uniform_machine(2, bw=1.0, L=0.1)
    trip = np.asarray([3.0, 1.0])  # class 0 3x slow -> replan fires

    mon = StragglerMonitor(2, threshold=1.3)
    mon.observe(np.ones(2))  # seed the EWMA baseline at nominal speed
    g1 = from_edges(4, edges)
    sched1, ev1 = mon.maybe_replan(1, g1, comp, m, trip)
    assert ev1 is not None
    base1 = mon._nominal_sched
    assert base1 is not None

    # rebuilt-but-equal graph and a fresh equal comp copy: cache must hit
    g2 = from_edges(4, list(edges))
    sched2, ev2 = mon.maybe_replan(2, g2, comp.copy(), m, trip)
    assert ev2 is not None
    assert mon._nominal_sched is base1, "content-equal inputs missed the cache"
    assert ev2.old_makespan == ev1.old_makespan

    # genuinely different costs: the baseline must be recomputed
    mon.maybe_replan(3, g2, comp * 2.0, m, trip)
    base3 = mon._nominal_sched
    assert base3 is not base1

    # instance counts are part of the key too (ceft_cpop schedules onto
    # m.inst_class): same L/bw/costs with a lost instance must not hit
    from repro.core import Machine
    m2 = Machine(L=m.L, bw=m.bw, counts=np.asarray([2, 1]))
    mon.maybe_replan(4, g2, comp * 2.0, m2, trip)
    assert mon._nominal_sched is not base3


def test_straggler_monitor_reroutes_critical_path():
    """Degrading the preferred class makes the re-planned schedule choose a
    different class for the critical path -- the paper's adaptivity claim."""
    cfg = C.get("glm4-9b")
    g, comp, m, _ = build_layer_dag(cfg, SHAPES["train_4k"], n_micro=2)
    mon = StragglerMonitor(m.P, threshold=1.3)
    sched0, ev0 = mon.maybe_replan(1, g, comp, m, np.ones(m.P))
    assert ev0 is None
    times = np.ones(m.P)
    times[0] = 3.0  # v5e-96 (train's preferred class) degrades 3x
    sched, ev = mon.maybe_replan(2, g, comp, m, times)
    assert ev is not None and ev.device_class == 0
    assert ev.new_makespan > ev.old_makespan  # degradation is reflected
    ic = m.inst_class
    used = set(ic[sched.proc].tolist())
    assert used - {0}, "replan still pins everything to the degraded class"
