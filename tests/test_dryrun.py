"""Dry-run machinery test: subprocess with a small fake fleet compiles smoke
cells on single- and multi-pod meshes and emits complete analysis records."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

CASES = [
    ("granite-3-8b", "train_4k", "single"),
    ("mixtral-8x22b", "decode_32k", "multi"),
    ("mamba2-2.7b", "long_500k", "multi"),
    ("whisper-tiny", "prefill_32k", "single"),
]


@pytest.mark.parametrize("arch,cell,mesh", CASES)
def test_dryrun_smoke_cell(arch, cell, mesh, tmp_path):
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--cell", cell, "--mesh", mesh, "--smoke", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=420,
    )
    rec_path = tmp_path / f"{arch}__{cell}__{mesh}.json"
    rec_err = ""
    if rec_path.exists():
        rec_err = json.loads(rec_path.read_text()).get("error", "")
    assert r.returncode == 0, (
        f"--- stdout ---\n{r.stdout}\n--- stderr ---\n{r.stderr}\n"
        f"--- record error ---\n{rec_err}"
    )
    rec = json.loads(rec_path.read_text())
    assert rec["ok"], rec.get("error")
    assert rec["cost_analysis"]["flops"] > 0
    assert rec["memory_analysis"]["argument_size_in_bytes"] > 0
    assert "collective_bytes" in rec["collectives"]


@pytest.mark.parametrize("profile,tag", [("baseline", ""), ("serve", "__serve")])
def test_roofline_analyze_cell_end_to_end(profile, tag, tmp_path):
    """ROADMAP smoke: run analyze_cell via repro.launch.roofline on a fake
    fleet (subprocess so the forced device count applies), not just its
    components."""
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.roofline", "--arch",
         "granite-3-8b", "--cell", "train_4k", "--mesh", "single", "--smoke",
         "--profile", profile, "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=420,
    )
    assert r.returncode == 0, f"--- stdout ---\n{r.stdout}\n--- stderr ---\n{r.stderr}"
    rec = json.loads(
        (tmp_path / f"granite-3-8b__train_4k__single{tag}.json").read_text())
    assert "error" not in rec, rec.get("error")
    assert rec["profile"] == profile
    assert rec["chips"] == 8
    for term in ("compute_s", "memory_s", "collective_s"):
        assert rec["terms"][term] >= 0
    assert rec["terms"]["compute_s"] > 0
    assert rec["components"], "no probes compiled"
    assert all(c["flops"] >= 0 for c in rec["components"].values())
