"""Optional-hypothesis shim: property tests auto-skip when hypothesis is
absent instead of crashing collection of the whole suite.

Usage in test modules (instead of ``from hypothesis import ...``):

    from _hyp import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Stand-in @given: marks the test skipped."""
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        """Stand-in @settings: identity decorator."""
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Just enough of hypothesis.strategies for module-level decorators."""

        @staticmethod
        def integers(*_a, **_k):
            return None

        @staticmethod
        def sampled_from(*_a, **_k):
            return None

        @staticmethod
        def floats(*_a, **_k):
            return None

        @staticmethod
        def booleans(*_a, **_k):
            return None

    st = _Strategies()
