"""Planner registry (ISSUE 10): every registered planner emits a feasible
schedule over the graph zoo, the Plan type honours the CeftResult duck-typing
contract, and the tournament's misidentification predicate agrees with the
brute-force oracle on small graphs."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    PLANNERS,
    ceft,
    ceft_cpop,
    heft,
    plan_with,
    planner_names,
    random_machine,
    realize_plan,
    validate_schedule,
)
from repro.core.bruteforce import bruteforce_cpl, chain_optimal_cost
from repro.core.planners import (
    averaged_path_misidentified,
    chain_optimal_assignment,
    get_planner,
)
from conftest import make_random_dag


def _workload(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 24))
    P = int(rng.integers(1, 5))
    g = make_random_dag(n, 0.3, rng)
    comp = rng.uniform(1, 10, size=(n, P))
    counts = rng.integers(1, 3, size=P)
    m = random_machine(P, rng, counts=counts, L_range=(0.0, 0.5))
    return g, comp, m


@given(st.integers(0, 10_000))
def test_every_planner_emits_a_feasible_schedule(seed):
    """The registry's core promise: any name, any zoo graph -> a Plan whose
    (proc, start, finish) is a valid schedule (precedence + comm + instance
    exclusivity), whose path vertices all live in the graph, and whose
    CeftResult-shaped surface is self-consistent."""
    g, comp, m = _workload(seed)
    for name in planner_names():
        try:
            p = plan_with(name, g, comp, m)
        except ValueError:
            assert get_planner(name).exhaustive  # only the oracle may bail
            continue
        validate_schedule(p, g, comp, m)
        assert p.planner == name
        assert p.eft.shape == comp.shape
        assert p.cpl > 0
        assert p.makespan == pytest.approx(float(p.finish.max()))
        assert len(p.cp_tasks) == len(p.cp_classes) >= 1
        assert all(0 <= t < g.n for t in p.cp_tasks)
        assert all(0 <= c < m.P for c in p.cp_classes)
        # the duck-typed CeftResult surface consumed by the router/deadlines
        assert p.path == list(zip(p.cp_tasks, p.cp_classes))
        assert p.assignment == dict(zip(p.cp_tasks, p.cp_classes))
        assert np.shares_memory(p.ceft, p.eft)


@given(st.integers(0, 10_000))
def test_registry_matches_direct_calls(seed):
    """plan('ceft_cpop') == ceft_cpop() and plan('heft') == heft(), instance
    for instance — the registry is a seam, not a reimplementation."""
    g, comp, m = _workload(seed)
    res = ceft(g, comp, m)
    p = plan_with("ceft_cpop", g, comp, m, ceft_result=res)
    direct = ceft_cpop(g, comp, m, res)
    assert np.array_equal(p.proc, direct.proc)
    assert np.array_equal(p.start, direct.start)
    assert np.array_equal(p.finish, direct.finish)
    assert p.cpl == pytest.approx(res.cpl)
    assert p.path == res.path
    ph = plan_with("heft", g, comp, m)
    dh = heft(g, comp, m)
    assert np.array_equal(ph.proc, dh.proc)
    assert np.array_equal(ph.finish, dh.finish)


@given(st.integers(0, 10_000))
def test_realize_is_idempotent_and_accepts_ceft_results(seed):
    g, comp, m = _workload(seed)
    res = ceft(g, comp, m)
    p = realize_plan("ceft_cpop", g, comp, m, res)
    validate_schedule(p, g, comp, m)
    assert realize_plan("ceft_cpop", g, comp, m, p) is p


def test_unknown_planner_fails_loudly():
    g, comp, m = _workload(0)
    with pytest.raises(KeyError, match="unknown planner"):
        plan_with("eft_of_the_gaps", g, comp, m)
    assert "bruteforce" in planner_names()
    assert "bruteforce" not in planner_names(include_exhaustive=False)


@settings(max_examples=40)
@given(st.integers(0, 10_000))
def test_chain_optimal_assignment_matches_chain_optimal_cost(seed):
    """The backtracking variant must return exactly the DP's optimum, and the
    class sequence it claims must price out to that cost."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 10))
    P = int(rng.integers(1, 4))
    from repro.core import from_edges
    g = from_edges(n, [(i, i + 1, float(rng.uniform(0.1, 5)))
                       for i in range(n - 1)])
    comp = rng.uniform(1, 10, size=(n, P))
    m = random_machine(P, rng, L_range=(0.0, 0.5))
    path = list(range(n))
    cost, classes = chain_optimal_assignment(path, g, comp, m)
    assert cost == pytest.approx(chain_optimal_cost(path, g, comp, m))
    assert len(classes) == n
    # re-price the claimed class sequence by hand
    t = comp[path[0], classes[0]]
    for i, (a, b) in enumerate(zip(path[:-1], path[1:])):
        data = float(g.parent_data(b)[np.nonzero(g.parents(b) == a)[0][0]])
        if classes[i + 1] != classes[i]:
            t += m.comm_class(data, classes[i], classes[i + 1])
        t += comp[b, classes[i + 1]]
    assert t == pytest.approx(cost)


@settings(max_examples=30)
@given(st.integers(0, 10_000))
def test_misid_counter_agrees_with_bruteforce_oracle(seed):
    """The tournament's misidentification predicate, cross-checked against
    the exhaustive oracle on small graphs.  CEFT's cpl is never below the
    brute-force longest chain-optimal path, and whenever the two are equal
    (the common, exact case) 'avg path strictly shorter than CEFT cpl' and
    'avg path strictly shorter than the oracle's true critical path' are the
    SAME predicate — the documented contract on
    :func:`averaged_path_misidentified`."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 12))
    P = int(rng.integers(2, 4))
    g = make_random_dag(n, 0.35, rng)
    comp = rng.uniform(1, 10, size=(n, P))
    m = random_machine(P, rng, L_range=(0.0, 0.5))
    res = ceft(g, comp, m)
    bf = bruteforce_cpl(g, comp, m)
    assert res.cpl >= bf - 1e-9 * max(1.0, abs(bf))
    from repro.core import averaged_critical_path
    _, avg_tasks = averaged_critical_path(g, comp, m)
    realized = chain_optimal_cost(avg_tasks, g, comp, m)
    mis = averaged_path_misidentified(g, comp, m, ceft_result=res)
    if res.cpl == pytest.approx(bf, rel=1e-9):
        oracle_mis = realized < bf * (1 - 1e-12)
        assert mis == bool(oracle_mis)
    else:
        # CEFT priced the constraint above every single path's optimum, so
        # the averaging-based path (one of those paths) is certainly not it
        assert mis


def test_bruteforce_plan_is_the_oracle():
    rng = np.random.default_rng(3)
    g = make_random_dag(10, 0.3, rng)
    comp = rng.uniform(1, 10, size=(10, 3))
    m = random_machine(3, rng)
    p = plan_with("bruteforce", g, comp, m)
    assert p.cpl == pytest.approx(bruteforce_cpl(g, comp, m))
    validate_schedule(p, g, comp, m)
    # CEFT is exact: its cpl equals the oracle's on any graph it can price
    assert ceft(g, comp, m).cpl >= p.cpl - 1e-9


def test_registry_is_complete():
    """Every scheduler the paper compares appears under its canonical name."""
    assert set(PLANNERS) == {"ceft_cpop", "cpop", "heft", "heft_down",
                             "ceft_heft_up", "ceft_heft_down", "bruteforce"}
