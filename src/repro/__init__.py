"""repro — CEFT (heterogeneous critical paths) as the scheduling brain of a
multi-pod JAX training/serving framework.  See DESIGN.md."""
__version__ = "1.0.0"
