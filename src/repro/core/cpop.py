"""CPOP (Algorithm 2, Topcuoglu et al. 2002) and CEFT-CPOP (paper §6).

CPOP computes rank_u + rank_d priorities from *mean* costs, walks the
same-priority chain from the entry task to get SET_CP, pins the whole set to the
single processor minimizing the set's total execution time, and list-schedules
by priority with insertion-based EFT for the rest.

CEFT-CPOP replaces lines 2-13: SET_CP is the CEFT critical path *with its
partial assignment* -- each CP task is pinned to an instance of its CEFT-chosen
class (consecutive same-class CP tasks share one instance, realizing the zero
co-location cost the DP assumed).  Everything else is unchanged, so makespan
differences isolate the quality of the critical path (paper §6).
"""
from __future__ import annotations

import numpy as np

from .ceft import CeftResult, ceft
from .machine import Machine
from .ranks import rank_d, rank_u
from .schedule import Schedule, list_schedule
from .taskgraph import TaskGraph


def _cpop_cp_set(g: TaskGraph, priority: np.ndarray) -> list[int]:
    """Walk from the max-priority entry following max-priority children
    (equal to |CP| in exact arithmetic; max is the float-robust form)."""
    srcs = g.sources
    t = int(srcs[np.argmax(priority[srcs])])
    cp = [t]
    while g.children(t).size:
        ch = g.children(t)
        t = int(ch[np.argmax(priority[ch])])
        cp.append(t)
    return cp


def cpop(g: TaskGraph, comp: np.ndarray, m: Machine) -> Schedule:
    pri = rank_u(g, comp, m) + rank_d(g, comp, m)
    cp = _cpop_cp_set(g, pri)
    ic = m.inst_class
    # p_cp: instance minimizing total CP computation (line 13)
    totals = comp[cp, :].sum(axis=0)          # per class
    p_cp = int(np.nonzero(ic == int(np.argmin(totals)))[0][0])
    pin = {t: p_cp for t in cp}
    return list_schedule(g, comp, m, priority=pri, pin=pin)


def cpop_cpl(g: TaskGraph, comp: np.ndarray, m: Machine) -> float:
    """The length of CPOP's critical path *under its partial schedule* -- the
    quantity Table 3 compares against CEFT's CPL.  CPOP maps its (mean-value)
    CP onto the single processor minimizing the set's total computation, which
    zeroes intra-path communication, so the realized length is

        min_p  sum_{t in SET_CP} C_comp(t, p).

    (The mean-value estimate |CP| = rank_u + rank_d of the entry task is
    exposed separately as ``cpop_cp_estimate``.)"""
    pri = rank_u(g, comp, m) + rank_d(g, comp, m)
    cp = _cpop_cp_set(g, pri)
    return float(comp[cp, :].sum(axis=0).min())


def cpop_cp_estimate(g: TaskGraph, comp: np.ndarray, m: Machine) -> float:
    """|CP| as Algorithm 2 line 6 estimates it (mean-value entry priority)."""
    pri = rank_u(g, comp, m) + rank_d(g, comp, m)
    return float(pri[g.sources].max())


def ceft_cpop(
    g: TaskGraph, comp: np.ndarray, m: Machine, ceft_result: CeftResult | None = None
) -> Schedule:
    res = ceft_result if ceft_result is not None else ceft(g, comp, m)
    pri = rank_u(g, comp, m) + rank_d(g, comp, m)
    ic = m.inst_class
    first_inst = {c: int(np.nonzero(ic == c)[0][0]) for c in range(m.P)}
    pin = {t: first_inst[p] for t, p in res.path}
    return list_schedule(g, comp, m, priority=pri, pin=pin)
