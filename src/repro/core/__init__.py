"""repro.core — the paper's contribution: CEFT and its schedulers."""
from .ceft import (
    CeftResult,
    averaged_critical_path,
    ceft,
    ceft_reference,
    chain_cost,
    min_comp_critical_path,
)
from .cpop import ceft_cpop, cpop, cpop_cpl
from .heft import ceft_heft_down, ceft_heft_up, heft, heft_down
from .machine import Machine, random_machine, uniform_machine
from .metrics import slack, slr, speedup
from .planners import (
    PLANNERS,
    Plan,
    PlannerSpec,
    averaged_path_misidentified,
    chain_optimal_assignment,
    get_planner,
    planner_names,
)
from .planners import plan as plan_with
from .planners import realize as realize_plan
from .ranks import rank_ceft_down, rank_ceft_up, rank_d, rank_u
from .schedule import Schedule, list_schedule, sequential_time, validate_schedule
from .taskgraph import (
    FusedLevelRun,
    LevelSegments,
    TaskGraph,
    csr_batch_segments,
    csr_level_segments,
    from_edge_arrays,
    from_edges,
    fuse_levels,
    linear_chain,
    moldable_fork_join,
    moldable_fork_join_arrays,
    padded_level_tables,
)

__all__ = [
    "CeftResult", "FusedLevelRun", "LevelSegments", "Machine", "PLANNERS",
    "Plan", "PlannerSpec", "Schedule",
    "TaskGraph", "averaged_critical_path", "averaged_path_misidentified",
    "ceft", "ceft_cpop", "chain_optimal_assignment", "get_planner",
    "plan_with", "planner_names", "realize_plan",
    "ceft_heft_down", "ceft_heft_up", "ceft_reference", "chain_cost", "cpop",
    "cpop_cpl", "csr_batch_segments", "csr_level_segments",
    "from_edge_arrays", "from_edges", "fuse_levels", "heft", "heft_down",
    "linear_chain", "list_schedule", "min_comp_critical_path",
    "moldable_fork_join", "moldable_fork_join_arrays",
    "padded_level_tables", "random_machine", "rank_ceft_down",
    "rank_ceft_up", "rank_d", "rank_u", "sequential_time", "slack", "slr",
    "speedup", "uniform_machine", "validate_schedule",
]
