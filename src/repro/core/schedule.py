"""Schedules, the insertion-based list-scheduling core, and the validator.

All three schedulers (HEFT, CPOP, CEFT-CPOP) share one engine: a ready queue
ordered by a priority vector, and insertion-based earliest-finish-time placement
on processor *instances* (Topcuoglu et al. 2002 §3.1).  The engine takes a
``pin`` map (task -> instance) so CPOP can pin CP tasks to p_cp and CEFT-CPOP can
pin them to their CEFT-assigned classes.
"""
from __future__ import annotations

import dataclasses
import heapq
from bisect import insort
from typing import Callable

import numpy as np

from .machine import Machine
from .taskgraph import TaskGraph


@dataclasses.dataclass
class Schedule:
    proc: np.ndarray    # (v,) instance id per task
    start: np.ndarray   # (v,)
    finish: np.ndarray  # (v,)

    @property
    def makespan(self) -> float:
        return float(self.finish.max())


class Timeline:
    """Busy intervals per processor instance, with gap-insertion EFT search."""

    def __init__(self, n_proc: int):
        self.busy: list[list[tuple[float, float]]] = [[] for _ in range(n_proc)]

    def earliest_start(self, p: int, ready: float, dur: float) -> float:
        prev_end = 0.0
        for s, e in self.busy[p]:
            t = max(ready, prev_end)
            if t + dur <= s + 1e-12:
                return t
            prev_end = max(prev_end, e)
        return max(ready, prev_end)

    def insert(self, p: int, s: float, e: float) -> None:
        insort(self.busy[p], (s, e))


def list_schedule(
    g: TaskGraph,
    comp: np.ndarray,
    m: Machine,
    priority: np.ndarray,
    pin: dict[int, int] | None = None,
) -> Schedule:
    """Priority-driven insertion-based list scheduling on instances.

    At every step the highest-priority *ready* task is popped; it is placed on
    its pinned instance if pinned, else on the instance minimizing its EFT.
    """
    v = g.n
    pin = pin or {}
    ic = m.inst_class
    n_proc = m.n_proc
    tl = Timeline(n_proc)
    proc = np.full(v, -1, np.int64)
    start = np.zeros(v, np.float64)
    finish = np.zeros(v, np.float64)
    indeg = g.in_degree.copy()
    inv_bw = 1.0 / m.bw            # (P, P) class view
    heap: list[tuple[float, int]] = []
    for s in np.nonzero(indeg == 0)[0]:
        heapq.heappush(heap, (-float(priority[s]), int(s)))
    scheduled = 0
    while heap:
        _, t = heapq.heappop(heap)
        ps = g.parents(t)
        pd = g.parent_data(t)
        # vectorized over candidate processors: ready time per instance
        ready = np.zeros(n_proc)
        for k, d in zip(ps, pd):
            ck = int(ic[proc[k]])
            vec = m.L[ck] + d * inv_bw[ck, ic]
            vec[proc[k]] = 0.0  # same instance: no transfer
            np.maximum(ready, finish[k] + vec, out=ready)
        cand = (pin[t],) if t in pin else range(n_proc)
        dur = comp[t, ic]
        best_eft, best_p, best_st = np.inf, -1, 0.0
        for p in cand:
            st = tl.earliest_start(p, float(ready[p]), float(dur[p]))
            if st + dur[p] < best_eft - 1e-15:
                best_eft, best_p, best_st = st + float(dur[p]), p, st
        proc[t] = best_p
        start[t] = best_st
        finish[t] = best_eft
        tl.insert(best_p, best_st, best_eft)
        scheduled += 1
        for c in g.children(t):
            indeg[c] -= 1
            if indeg[c] == 0:
                heapq.heappush(heap, (-float(priority[c]), int(c)))
    if scheduled != v:
        raise RuntimeError("graph has a cycle or disconnected indegrees")
    return Schedule(proc, start, finish)


def validate_schedule(
    sched: Schedule, g: TaskGraph, comp: np.ndarray, m: Machine, tol: float = 1e-9
) -> None:
    """Raise AssertionError unless the schedule is legal: correct durations,
    precedence + communication respected, instances exclusive."""
    ic = m.inst_class
    v = g.n
    dur = comp[np.arange(v), ic[sched.proc]]
    assert np.allclose(sched.finish, sched.start + dur, atol=tol), "duration mismatch"
    assert (sched.start >= -tol).all(), "negative start"
    for i in range(v):
        for j, d in zip(g.children(i), g.child_data(i)):
            c = m.comm_inst(float(d), int(sched.proc[i]), int(sched.proc[j]))
            assert sched.start[j] + tol >= sched.finish[i] + c, (
                f"precedence violated on edge {i}->{j}"
            )
    for p in range(m.n_proc):
        ts = np.nonzero(sched.proc == p)[0]
        if ts.size < 2:
            continue
        order = ts[np.argsort(sched.start[ts])]
        ends = sched.finish[order][:-1]
        starts = sched.start[order][1:]
        assert (starts + tol >= ends).all(), f"overlap on processor {p}"


def sequential_time(comp: np.ndarray, m: Machine) -> float:
    """Numerator of speedup (eq. 8): all tasks on the single processor that
    minimizes total execution time."""
    return float(comp.sum(axis=0).min())
