"""Task ranking functions.

Mean-value ranks (Topcuoglu et al. 2002, used by HEFT/CPOP):

    rank_u(i) = wbar_i + max_{j in succ(i)} ( cbar_ij + rank_u(j) )
    rank_d(i) = max_{k in pred(i)} ( rank_d(k) + wbar_k + cbar_ki )

CEFT-based ranks (paper §8.2):

    rank_ceft_down(i) = min_p CEFT(i, p)            (accurate downward length)
    rank_ceft_up(i)   = min_p CEFT_T(i', p)          (CEFT on the edge-transposed
                                                     DAG, i' the relabelled id)
"""
from __future__ import annotations

import numpy as np

from .ceft import ceft
from .machine import Machine
from .taskgraph import TaskGraph


def mean_costs(g: TaskGraph, comp: np.ndarray, m: Machine):
    wbar = m.mean_comp(comp)
    cbar = m.mean_comm(g.cdata)  # aligned with children CSR
    return wbar, cbar


def rank_u(g: TaskGraph, comp: np.ndarray, m: Machine) -> np.ndarray:
    wbar, cbar = mean_costs(g, comp, m)
    r = np.zeros(g.n, np.float64)
    for i in range(g.n - 1, -1, -1):
        lo, hi = g.cindptr[i], g.cindptr[i + 1]
        best = 0.0
        for j, c in zip(g.cindices[lo:hi], np.atleast_1d(cbar)[lo:hi]):
            best = max(best, c + r[j])
        r[i] = wbar[i] + best
    return r


def rank_d(g: TaskGraph, comp: np.ndarray, m: Machine) -> np.ndarray:
    wbar, cbar = mean_costs(g, comp, m)
    r = np.zeros(g.n, np.float64)
    for i in range(g.n):
        lo, hi = g.cindptr[i], g.cindptr[i + 1]
        for j, c in zip(g.cindices[lo:hi], np.atleast_1d(cbar)[lo:hi]):
            r[j] = max(r[j], r[i] + wbar[i] + c)
    return r


def rank_ceft_down(g: TaskGraph, comp: np.ndarray, m: Machine) -> np.ndarray:
    res = ceft(g, comp, m)
    return res.ceft.min(axis=1)


def rank_ceft_up(g: TaskGraph, comp: np.ndarray, m: Machine) -> np.ndarray:
    gt = g.transpose()
    # transpose() relabels vertex i -> n-1-i; costs follow the task identity
    comp_t = comp[::-1]
    res = ceft(gt, comp_t, m)
    up = res.ceft.min(axis=1)
    return up[::-1]
