"""Exponential-time oracles used only in tests (small graphs).

CEFT's semantics (paper §4/§4.1): under task duplication, the critical path is
the source->sink path maximizing its *chain-optimal* cost, where the chain cost
of a path is minimized over all assignments of its tasks to classes (exact by
DP over the processor state along the chain).  The oracle enumerates every path
and runs the exact chain DP, giving:

    bf = max_{paths pi} min_{assignments} cost(pi)

Invariant (proved by induction on the recurrence): CEFT_cpl >= bf, with equality
in the common case (the recurrence computes min_l max_pi >= max_pi min_l).
"""
from __future__ import annotations

import numpy as np

from .machine import Machine
from .taskgraph import TaskGraph


def all_paths(g: TaskGraph) -> list[list[int]]:
    out: list[list[int]] = []
    stack: list[list[int]] = [[int(s)] for s in g.sources]
    while stack:
        p = stack.pop()
        ch = g.children(p[-1])
        if ch.size == 0:
            out.append(p)
        else:
            for c in ch:
                stack.append(p + [int(c)])
    return out


def chain_optimal_cost(path: list[int], g: TaskGraph, comp: np.ndarray, m: Machine) -> float:
    """Exact min over assignments of the chain cost (DP over the class of the
    current task -- optimal because a chain's cost is Markov in that class)."""
    P = comp.shape[1]
    dp = comp[path[0], :].astype(np.float64).copy()
    for a, b in zip(path[:-1], path[1:]):
        ps = g.parents(b)
        data = float(g.parent_data(b)[np.nonzero(ps == a)[0][0]])
        comm = (m.L[:, None] + data / m.bw) * (~np.eye(P, dtype=bool))
        dp = comp[b, :] + (dp[:, None] + comm).min(axis=0)
    return float(dp.min())


def bruteforce_cpl(g: TaskGraph, comp: np.ndarray, m: Machine) -> float:
    """max over all source->sink paths of the chain-optimal cost."""
    return max(chain_optimal_cost(p, g, comp, m) for p in all_paths(g))
