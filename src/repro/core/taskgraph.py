"""Task-graph representation (paper §3.1).

A task graph is a weighted DAG G_t(V_t, E_t): vertices are tasks, edges carry the
data volume communicated from a parent task to a child task.  We keep the graph in
CSR form in both directions (children and parents), require vertex ids to be a
topological order (the paper's Algorithm 1 assumes this), and pre-compute the
longest-path *level* of every vertex so the vectorized CEFT sweep can process one
level at a time.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TaskGraph:
    n: int
    # children CSR: edges (i -> cindices[cindptr[i]:cindptr[i+1]])
    cindptr: np.ndarray
    cindices: np.ndarray
    cdata: np.ndarray  # data volume per child edge
    # parents CSR (transpose), aligned data
    pindptr: np.ndarray
    pindices: np.ndarray
    pdata: np.ndarray
    # longest-path depth of each vertex (sources are level 0)
    level: np.ndarray

    # ------------------------------------------------------------------ basics
    @property
    def n_edges(self) -> int:
        return int(self.cindices.shape[0])

    def children(self, i: int) -> np.ndarray:
        return self.cindices[self.cindptr[i] : self.cindptr[i + 1]]

    def child_data(self, i: int) -> np.ndarray:
        return self.cdata[self.cindptr[i] : self.cindptr[i + 1]]

    def parents(self, i: int) -> np.ndarray:
        return self.pindices[self.pindptr[i] : self.pindptr[i + 1]]

    def parent_data(self, i: int) -> np.ndarray:
        return self.pdata[self.pindptr[i] : self.pindptr[i + 1]]

    @property
    def in_degree(self) -> np.ndarray:
        return np.diff(self.pindptr)

    @property
    def out_degree(self) -> np.ndarray:
        return np.diff(self.cindptr)

    @property
    def sources(self) -> np.ndarray:
        return np.nonzero(self.in_degree == 0)[0]

    @property
    def sinks(self) -> np.ndarray:
        return np.nonzero(self.out_degree == 0)[0]

    @property
    def n_levels(self) -> int:
        return int(self.level.max()) + 1 if self.n else 0

    def levels(self) -> list[np.ndarray]:
        """Vertices grouped by longest-path depth (each a topological batch)."""
        order = np.argsort(self.level, kind="stable")
        bounds = np.searchsorted(self.level[order], np.arange(self.n_levels + 1))
        return [order[bounds[k] : bounds[k + 1]] for k in range(self.n_levels)]

    # --------------------------------------------------------------- transforms
    def transpose(self) -> "TaskGraph":
        """Edge-reversed graph (paper §8.2: rank_ceft_up runs CEFT on G^T).

        Vertex ids are relabelled as ``n-1-i`` so that ids remain a topological
        order of the transposed graph.
        """
        n = self.n
        remap = n - 1 - np.arange(n)
        edges = []
        for i in range(n):
            for j, d in zip(self.children(i), self.child_data(i)):
                edges.append((remap[j], remap[i], d))
        return from_edges(n, edges)

    def with_virtual_source_sink(self) -> tuple["TaskGraph", int, int]:
        """Add a zero-cost virtual entry/exit if the graph has several of either.

        Returns (graph, vsrc, vsink) where vsrc/vsink are -1 when not added.
        Virtual vertices get id 0 / n+? while preserving topological ids.
        """
        srcs, snks = self.sources, self.sinks
        add_src = len(srcs) > 1
        add_snk = len(snks) > 1
        if not add_src and not add_snk:
            return self, -1, -1
        off = 1 if add_src else 0
        n = self.n + off + (1 if add_snk else 0)
        edges: list[tuple[int, int, float]] = []
        for i in range(self.n):
            for j, d in zip(self.children(i), self.child_data(i)):
                edges.append((i + off, j + off, float(d)))
        vsrc = 0 if add_src else -1
        vsink = n - 1 if add_snk else -1
        if add_src:
            for s in srcs:
                edges.append((0, int(s) + off, 0.0))
        if add_snk:
            for s in snks:
                edges.append((int(s) + off, n - 1, 0.0))
        return from_edges(n, edges), vsrc, vsink


def from_edges(
    n: int, edges: Iterable[tuple[int, int, float]], *, sort_topologically: bool = False
) -> TaskGraph:
    """Build a TaskGraph from (src, dst, data) triples.

    Vertex ids must already be a topological order (src < dst) unless
    ``sort_topologically`` is set, in which case we relabel via Kahn's algorithm.
    """
    e = list(edges)
    if e:
        src = np.asarray([x[0] for x in e], dtype=np.int32)
        dst = np.asarray([x[1] for x in e], dtype=np.int32)
        dat = np.asarray([x[2] for x in e], dtype=np.float64)
    else:
        src = np.zeros(0, np.int32)
        dst = np.zeros(0, np.int32)
        dat = np.zeros(0, np.float64)
    if src.size and not (src < dst).all():
        if not sort_topologically:
            raise ValueError("edges must satisfy src < dst (topological ids); "
                             "pass sort_topologically=True to relabel")
        order = _topo_order(n, src, dst)
        rank = np.empty(n, np.int32)
        rank[order] = np.arange(n, dtype=np.int32)
        src, dst = rank[src], rank[dst]
        if not (src < dst).all():  # pragma: no cover - cycle
            raise ValueError("graph has a cycle")

    def csr(a: np.ndarray, b: np.ndarray, d: np.ndarray):
        order = np.lexsort((b, a))
        a, b, d = a[order], b[order], d[order]
        indptr = np.zeros(n + 1, np.int64)
        np.add.at(indptr, a + 1, 1)
        np.cumsum(indptr, out=indptr)
        return indptr, b.astype(np.int32), d

    cindptr, cindices, cdata = csr(src, dst, dat)
    pindptr, pindices, pdata = csr(dst, src, dat)

    level = np.zeros(n, np.int32)
    for i in range(n):  # ids are topological, single pass suffices
        ps = pindices[pindptr[i] : pindptr[i + 1]]
        if ps.size:
            level[i] = level[ps].max() + 1
    return TaskGraph(n, cindptr, cindices, cdata, pindptr, pindices, pdata, level)


def _topo_order(n: int, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    indeg = np.zeros(n, np.int64)
    np.add.at(indeg, dst, 1)
    adj: list[list[int]] = [[] for _ in range(n)]
    for a, b in zip(src.tolist(), dst.tolist()):
        adj[a].append(b)
    stack = [i for i in range(n) if indeg[i] == 0]
    out = []
    while stack:
        i = stack.pop()
        out.append(i)
        for j in adj[i]:
            indeg[j] -= 1
            if indeg[j] == 0:
                stack.append(j)
    if len(out) != n:
        raise ValueError("graph has a cycle")
    return np.asarray(out, dtype=np.int32)


def linear_chain(n: int, data: float = 1.0) -> TaskGraph:
    return from_edges(n, [(i, i + 1, data) for i in range(n - 1)])


def padded_level_tables(g: TaskGraph) -> dict[str, np.ndarray]:
    """Fixed-shape per-level tables for the jittable CEFT sweep.

    Returns arrays padded to (n_levels, max_width) and (n_levels, max_width, dmax):
      tasks  : vertex id or -1
      par    : parent vertex id or -1
      pdata  : data volume on the parent edge (0 where padded)
    Level 0 rows are sources (no parents).
    """
    lvls = g.levels()
    n_levels = len(lvls)
    width = max((len(l) for l in lvls), default=0)
    dmax = max(1, int(g.in_degree.max()) if g.n else 1)
    tasks = np.full((n_levels, width), -1, np.int32)
    par = np.full((n_levels, width, dmax), -1, np.int32)
    pdat = np.zeros((n_levels, width, dmax), np.float32)
    for li, l in enumerate(lvls):
        tasks[li, : len(l)] = l
        for wi, t in enumerate(l):
            ps = g.parents(int(t))
            ds = g.parent_data(int(t))
            par[li, wi, : len(ps)] = ps
            pdat[li, wi, : len(ps)] = ds
    return {"tasks": tasks, "par": par, "pdata": pdat}
