"""Task-graph representation (paper §3.1).

A task graph is a weighted DAG G_t(V_t, E_t): vertices are tasks, edges carry the
data volume communicated from a parent task to a child task.  We keep the graph in
CSR form in both directions (children and parents), require vertex ids to be a
topological order (the paper's Algorithm 1 assumes this), and pre-compute the
longest-path *level* of every vertex so the vectorized CEFT sweep can process one
level at a time.

This module is the only place that builds level tables for the device sweeps:
``padded_level_tables`` (the dense (n_levels, Wmax, Dmax) form) and
``csr_level_segments`` (the edge-centric CSR form whose total size is O(v + e)).
Everything else must consume these structures, not rebuild them.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TaskGraph:
    n: int
    # children CSR: edges (i -> cindices[cindptr[i]:cindptr[i+1]])
    cindptr: np.ndarray
    cindices: np.ndarray
    cdata: np.ndarray  # data volume per child edge
    # parents CSR (transpose), aligned data
    pindptr: np.ndarray
    pindices: np.ndarray
    pdata: np.ndarray
    # longest-path depth of each vertex (sources are level 0)
    level: np.ndarray

    # ------------------------------------------------------------------ basics
    @property
    def n_edges(self) -> int:
        return int(self.cindices.shape[0])

    def children(self, i: int) -> np.ndarray:
        return self.cindices[self.cindptr[i] : self.cindptr[i + 1]]

    def child_data(self, i: int) -> np.ndarray:
        return self.cdata[self.cindptr[i] : self.cindptr[i + 1]]

    def parents(self, i: int) -> np.ndarray:
        return self.pindices[self.pindptr[i] : self.pindptr[i + 1]]

    def parent_data(self, i: int) -> np.ndarray:
        return self.pdata[self.pindptr[i] : self.pindptr[i + 1]]

    @property
    def in_degree(self) -> np.ndarray:
        return np.diff(self.pindptr)

    @property
    def out_degree(self) -> np.ndarray:
        return np.diff(self.cindptr)

    @property
    def sources(self) -> np.ndarray:
        return np.nonzero(self.in_degree == 0)[0]

    @property
    def sinks(self) -> np.ndarray:
        return np.nonzero(self.out_degree == 0)[0]

    @property
    def n_levels(self) -> int:
        return int(self.level.max()) + 1 if self.n else 0

    def levels(self) -> list[np.ndarray]:
        """Vertices grouped by longest-path depth (each a topological batch)."""
        order, bounds = _level_order(self)
        return [order[bounds[k] : bounds[k + 1]] for k in range(self.n_levels)]

    # --------------------------------------------------------------- transforms
    def transpose(self) -> "TaskGraph":
        """Edge-reversed graph (paper §8.2: rank_ceft_up runs CEFT on G^T).

        Vertex ids are relabelled as ``n-1-i`` so that ids remain a topological
        order of the transposed graph.
        """
        n = self.n
        remap = n - 1 - np.arange(n, dtype=np.int32)
        src = np.repeat(np.arange(n, dtype=np.int32), self.out_degree)
        return from_edge_arrays(n, remap[self.cindices], remap[src], self.cdata)

    def with_virtual_source_sink(self) -> tuple["TaskGraph", int, int]:
        """Add a zero-cost virtual entry/exit if the graph has several of either.

        Returns (graph, vsrc, vsink) where vsrc/vsink are -1 when not added.
        Virtual vertices get id 0 / n+? while preserving topological ids.
        """
        srcs, snks = self.sources, self.sinks
        add_src = len(srcs) > 1
        add_snk = len(snks) > 1
        if not add_src and not add_snk:
            return self, -1, -1
        off = 1 if add_src else 0
        n = self.n + off + (1 if add_snk else 0)
        src = np.repeat(np.arange(self.n, dtype=np.int64), self.out_degree) + off
        dst = self.cindices.astype(np.int64) + off
        dat = self.cdata.astype(np.float64)
        vsrc = 0 if add_src else -1
        vsink = n - 1 if add_snk else -1
        if add_src:
            src = np.concatenate([src, np.zeros(len(srcs), np.int64)])
            dst = np.concatenate([dst, srcs.astype(np.int64) + off])
            dat = np.concatenate([dat, np.zeros(len(srcs))])
        if add_snk:
            src = np.concatenate([src, snks.astype(np.int64) + off])
            dst = np.concatenate([dst, np.full(len(snks), n - 1, np.int64)])
            dat = np.concatenate([dat, np.zeros(len(snks))])
        return from_edge_arrays(n, src, dst, dat), vsrc, vsink


def graph_fingerprint(g: TaskGraph) -> bytes:
    """Content digest of a graph's structure and edge weights.

    Two graphs with equal fingerprints are interchangeable for every level
    table / segment structure this module builds (the children CSR determines
    the graph completely; the parent CSR and levels are derived from it).
    Used by the plan cache (repro.sched.plancache) to key plans by *value*,
    so a rebuilt-but-equal graph hits instead of re-sweeping.
    """
    import hashlib

    h = hashlib.sha1()
    h.update(np.int64(g.n).tobytes())
    for a in (g.cindptr, g.cindices, g.cdata):
        a = np.ascontiguousarray(a)
        h.update(a.dtype.str.encode())
        h.update(a.tobytes())
    return h.digest()


def _csr_ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Flat indices [starts[i] .. starts[i]+counts[i]) concatenated (the
    vectorized multi-row CSR gather)."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    first = np.cumsum(counts) - counts
    return np.repeat(starts, counts) + (np.arange(total) - np.repeat(first, counts))


def from_edge_arrays(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    data: np.ndarray,
    *,
    sort_topologically: bool = False,
) -> TaskGraph:
    """Array form of :func:`from_edges` — the fast path for large graphs
    (no Python loop over edges anywhere in the build)."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    dat = np.asarray(data, dtype=np.float64)
    if src.size and not (src < dst).all():
        if not sort_topologically:
            raise ValueError("edges must satisfy src < dst (topological ids); "
                             "pass sort_topologically=True to relabel")
        order = _topo_order(n, src, dst)
        rank = np.empty(n, np.int32)
        rank[order] = np.arange(n, dtype=np.int32)
        src, dst = rank[src], rank[dst]
        if not (src < dst).all():  # pragma: no cover - cycle
            raise ValueError("graph has a cycle")

    def csr(a: np.ndarray, b: np.ndarray, d: np.ndarray):
        order = np.lexsort((b, a))
        a, b, d = a[order], b[order], d[order]
        indptr = np.zeros(n + 1, np.int64)
        np.add.at(indptr, a + 1, 1)
        np.cumsum(indptr, out=indptr)
        return indptr, b.astype(np.int32), d

    cindptr, cindices, cdata = csr(src, dst, dat)
    pindptr, pindices, pdata = csr(dst, src, dat)
    level = _levels_from_csr(n, cindptr, cindices, pindptr)
    return TaskGraph(n, cindptr, cindices, cdata, pindptr, pindices, pdata, level)


def from_edges(
    n: int, edges: Iterable[tuple[int, int, float]], *, sort_topologically: bool = False
) -> TaskGraph:
    """Build a TaskGraph from (src, dst, data) triples.

    Vertex ids must already be a topological order (src < dst) unless
    ``sort_topologically`` is set, in which case we relabel via Kahn's algorithm.
    """
    e = list(edges)
    if e:
        arr = np.asarray(e, dtype=np.float64).reshape(len(e), 3)
        src = arr[:, 0].astype(np.int32)
        dst = arr[:, 1].astype(np.int32)
        dat = arr[:, 2]
    else:
        src = np.zeros(0, np.int32)
        dst = np.zeros(0, np.int32)
        dat = np.zeros(0, np.float64)
    return from_edge_arrays(n, src, dst, dat, sort_topologically=sort_topologically)


def _levels_from_csr(
    n: int, cindptr: np.ndarray, cindices: np.ndarray, pindptr: np.ndarray
) -> np.ndarray:
    """Longest-path depth of every vertex, one vectorized wavefront per level
    (replaces the per-vertex Python loop; O(depth) numpy passes)."""
    level = np.zeros(n, np.int32)
    remaining = np.diff(pindptr).astype(np.int64)
    frontier = np.nonzero(remaining == 0)[0]
    while frontier.size:
        counts = cindptr[frontier + 1] - cindptr[frontier]
        offs = _csr_ranges(cindptr[frontier], counts)
        if offs.size == 0:
            break
        dst = cindices[offs]
        np.maximum.at(level, dst, np.repeat(level[frontier] + 1, counts))
        np.add.at(remaining, dst, -1)
        frontier = np.unique(dst[remaining[dst] == 0])
    return level


def _topo_order(n: int, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    indeg = np.zeros(n, np.int64)
    np.add.at(indeg, dst, 1)
    adj: list[list[int]] = [[] for _ in range(n)]
    for a, b in zip(src.tolist(), dst.tolist()):
        adj[a].append(b)
    stack = [i for i in range(n) if indeg[i] == 0]
    out = []
    while stack:
        i = stack.pop()
        out.append(i)
        for j in adj[i]:
            indeg[j] -= 1
            if indeg[j] == 0:
                stack.append(j)
    if len(out) != n:
        raise ValueError("graph has a cycle")
    return np.asarray(out, dtype=np.int32)


def linear_chain(n: int, data: float = 1.0) -> TaskGraph:
    return from_edges(n, [(i, i + 1, data) for i in range(n - 1)])


def moldable_fork_join_arrays(
    volumes: np.ndarray, split: int
) -> tuple[int, np.ndarray, np.ndarray, np.ndarray]:
    """Edge arrays for a *moldable* fork-join batch DAG (Wang & Sinnen).

    ``volumes[i]`` is batch ``i``'s divisible work (a request class's prefill
    token volume); ``split`` is the planner-chosen degree d.  Each batch
    becomes d parallel chunk tasks (vertices ``i*d .. i*d+d-1``, volume/d
    each) joining into one sink task (vertex ``n_batches*d + i``), with edge
    data the chunk volume — the KV handoff cost a join pays per chunk that
    lands on a different class.  ``split=1`` reproduces the classic
    prefill->decode chain arrays byte-for-byte, which is what keeps the
    router's content-keyed graph store hitting for unsplit plans.

    Returns ``(n, src, dst, data)`` ready for :func:`from_edge_arrays` (chunk
    ids precede join ids, so vertex ids are already topological).
    """
    volumes = np.asarray(volumes, np.float64)
    G = int(volumes.size)
    d = int(split)
    if d < 1:
        raise ValueError(f"split degree must be >= 1, got {d}")
    src = np.arange(G * d, dtype=np.int32)
    dst = (G * d + src // d).astype(np.int32)
    data = np.repeat(volumes / d, d)
    return G * d + G, src, dst, data


def moldable_fork_join(volumes: np.ndarray, split: int) -> TaskGraph:
    """:func:`moldable_fork_join_arrays` built into a TaskGraph (the graph-zoo
    / tournament entry point; the router keeps the raw arrays for the
    content-keyed graph store)."""
    return from_edge_arrays(*moldable_fork_join_arrays(volumes, split))


# --------------------------------------------------------------- level tables
def _level_order(g: TaskGraph) -> tuple[np.ndarray, np.ndarray]:
    """(order, bounds): vertices stably sorted by level (ascending id within a
    level) and the per-level start offsets into ``order``."""
    order = np.argsort(g.level, kind="stable")
    bounds = np.searchsorted(g.level[order], np.arange(g.n_levels + 1))
    return order, bounds


def _slots_from_order(g: TaskGraph, order: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    """Within-level position of every vertex under the :meth:`TaskGraph.levels`
    ordering (ascending vertex id within a level)."""
    slot = np.empty(g.n, np.int32)
    slot[order] = (np.arange(g.n) - bounds[g.level[order]]).astype(np.int32)
    return slot


def padded_level_tables(g: TaskGraph) -> dict[str, np.ndarray]:
    """Fixed-shape per-level tables for the jittable CEFT sweep.

    Returns arrays padded to (n_levels, max_width) and (n_levels, max_width, dmax):
      tasks  : vertex id or -1
      par    : parent vertex id or -1
      pdata  : data volume on the parent edge (0 where padded)
    Level 0 rows are sources (no parents).
    """
    order, bounds = _level_order(g)
    n_levels = g.n_levels
    widths = np.diff(bounds)
    width = int(widths.max()) if n_levels else 0
    indeg = g.in_degree
    dmax = max(1, int(indeg.max()) if g.n else 1)
    tasks = np.full((n_levels, width), -1, np.int32)
    par = np.full((n_levels, width, dmax), -1, np.int32)
    pdat = np.zeros((n_levels, width, dmax), np.float32)
    if g.n == 0:
        return {"tasks": tasks, "par": par, "pdata": pdat}
    slot = _slots_from_order(g, order, bounds)
    tasks[g.level[order], slot[order]] = order
    # scatter every parent edge into its (level, slot, k) cell in one pass
    edst = np.repeat(np.arange(g.n, dtype=np.int64), indeg)
    k = np.arange(g.n_edges) - np.repeat(g.pindptr[:-1], indeg)
    par[g.level[edst], slot[edst], k] = g.pindices
    pdat[g.level[edst], slot[edst], k] = g.pdata
    return {"tasks": tasks, "par": par, "pdata": pdat}


@dataclasses.dataclass(frozen=True)
class LevelSegments:
    """Edge-centric CSR level structure: the O(v + e) alternative to
    :func:`padded_level_tables` (ISSUE 3; paper §5's O(P²e) bound).

    Vertices are ordered by (level, id); each level's parent edges form one
    contiguous run, ordered by (child slot, parent id) so per-child segments
    are contiguous and tie-breaking matches the dense formulation (first
    maximal parent in ascending-id order wins).

      task_ids    : (n,)  vertex ids sorted by (level, id)
      task_bounds : (n_levels+1,) level k's tasks are task_ids[tb[k]:tb[k+1]]
      edge_src    : (e,)  parent vertex id per edge
      edge_data   : (e,)  data volume per edge
      edge_seg    : (e,)  within-level slot of the child vertex (segment id)
      edge_bounds : (n_levels+1,) level k's edges are rows eb[k]:eb[k+1]
    """
    task_ids: np.ndarray
    task_bounds: np.ndarray
    edge_src: np.ndarray
    edge_data: np.ndarray
    edge_seg: np.ndarray
    edge_bounds: np.ndarray

    @property
    def n_levels(self) -> int:
        return int(self.task_bounds.shape[0]) - 1

    def level_tasks(self, k: int) -> np.ndarray:
        return self.task_ids[self.task_bounds[k] : self.task_bounds[k + 1]]

    def level_edges(self, k: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        s = slice(self.edge_bounds[k], self.edge_bounds[k + 1])
        return self.edge_src[s], self.edge_data[s], self.edge_seg[s]


@dataclasses.dataclass(frozen=True)
class FusedLevelRun:
    """Stacked super-step tables: a run of adjacent levels sharing one padded
    shape, stacked along a leading axis so a device sweep can ``lax.scan`` the
    whole run in a single dispatch (ISSUE 4) instead of one Python-level call
    per level.

      tasks     : (R, W) vertex ids, padded with the caller's pad vertex
      edge_src  : (R, E) parent vertex id per edge, padded with the pad vertex
      edge_data : (R, E) data volume per edge (0 where padded)
      edge_seg  : (R, E) within-level child slot, padded with W - 1
      e_real    : (R,)   real (unpadded) edge count per level
      width     : W — the per-level segment count (padding slots included)

    Rows past the run's natural length are no-op levels (all-padding tasks and
    edges, ``e_real == 0``): a sweep may execute them freely, they only touch
    the padding scratch slot.
    """
    tasks: np.ndarray
    edge_src: np.ndarray
    edge_data: np.ndarray
    edge_seg: np.ndarray
    e_real: np.ndarray
    width: int

    @property
    def n_levels(self) -> int:
        return int(self.tasks.shape[0])


def fuse_levels(
    segs: LevelSegments,
    widths: Sequence[int],
    edge_caps: Sequence[int],
    *,
    pad_vertex: int,
    pad_run: "Callable[[int], int] | None" = None,
    run_ids: "Sequence[int] | None" = None,
) -> list[FusedLevelRun]:
    """Group adjacent levels landing in the same padded shape into stacked
    super-step tables.

    ``widths[k-1]`` / ``edge_caps[k-1]`` give level ``k``'s padded task/edge
    capacity for ``k in [1, n_levels)`` — the *caller* chooses them (the pow2
    bucket policy is owned by core/ceft_jax.py; this pass only groups equal
    shapes).  Level 0 (sources, no parent edges) is never part of a run.
    ``pad_run`` optionally maps a run's natural length to its padded length;
    appended levels are no-ops (see :class:`FusedLevelRun`).

    ``run_ids`` (aligned with ``widths``) makes the grouping explicit instead
    of by-equal-shape: adjacent levels group iff they share a non-negative
    run id, and levels with a negative id are skipped entirely (the caller
    builds those through another layout, e.g. :func:`fuse_levels_dense`).
    """
    n_levels = segs.n_levels
    if n_levels > 1 and (len(widths) != n_levels - 1 or len(edge_caps) != n_levels - 1):
        raise ValueError("need one (width, edge_cap) per level in [1, n_levels)")
    if run_ids is not None and len(run_ids) != n_levels - 1:
        raise ValueError("need one run id per level in [1, n_levels)")

    def same_group(a: int, b: int) -> bool:
        if run_ids is not None:
            return run_ids[a - 1] == run_ids[b - 1]
        return (int(widths[a - 1]), int(edge_caps[a - 1])) == (
            int(widths[b - 1]), int(edge_caps[b - 1]))

    runs: list[FusedLevelRun] = []
    k = 1
    while k < n_levels:
        if run_ids is not None and run_ids[k - 1] < 0:
            k += 1
            continue
        j = k
        key = (int(widths[k - 1]), int(edge_caps[k - 1]))
        while j + 1 < n_levels and same_group(k, j + 1):
            j += 1
            if (int(widths[j - 1]), int(edge_caps[j - 1])) != key:
                raise ValueError("a run must share one (width, edge_cap)")
        W, E = key
        R = j - k + 1
        R_pad = int(pad_run(R)) if pad_run is not None else R
        tasks = np.full((R_pad, W), pad_vertex, np.int32)
        src = np.full((R_pad, E), pad_vertex, np.int32)
        dat = np.zeros((R_pad, E), np.float32)
        seg = np.full((R_pad, E), W - 1, np.int32)
        e_real = np.zeros(R_pad, np.int32)
        for r, lv in enumerate(range(k, j + 1)):
            t = segs.level_tasks(lv)
            es, ed, eg = segs.level_edges(lv)
            if len(t) > W or len(es) > E:
                raise ValueError(f"level {lv} exceeds its padded shape {key}")
            tasks[r, : len(t)] = t
            src[r, : len(es)] = es
            dat[r, : len(es)] = ed
            seg[r, : len(es)] = eg
            e_real[r] = len(es)
        runs.append(FusedLevelRun(tasks, src, dat, seg, e_real, W))
        k = j + 1
    return runs


@dataclasses.dataclass(frozen=True)
class FusedDenseRun:
    """Dense-layout super-step tables: a run of adjacent levels stacked into
    run-local (R, W, D) padded parent tables (the `padded_level_tables` form
    restricted to one run and its own width/fan-in buckets).

    The device sweep picks this layout for runs with no *within-level*
    in-degree skew (W·D ≈ E): the dense contraction then does the same work
    as the segment form with cheaper per-level reductions.  Padding follows
    `padded_level_tables`: vertex/parent ids -1, data 0; rows past the run's
    natural length are all-padding no-op levels.
    """
    tasks: np.ndarray   # (R, W) vertex ids, -1 padded
    par: np.ndarray     # (R, W, D) parent vertex ids, -1 padded
    pdata: np.ndarray   # (R, W, D) data volume per parent edge (0 padded)

    @property
    def n_levels(self) -> int:
        return int(self.tasks.shape[0])


def fuse_levels_dense(
    segs: LevelSegments,
    start: int,
    stop: int,
    width: int,
    depth: int,
    *,
    pad_run: "Callable[[int], int] | None" = None,
) -> FusedDenseRun:
    """Build one run's dense (R, width, depth) tables for levels [start, stop)
    directly from the CSR segments — O(run edges) host work at the caller's
    *run-local* buckets.  (Slicing graph-global `padded_level_tables` would
    cost O(n_levels·Wmax·Dmax) to extract a narrow run, reintroducing the
    padding blowup the fused sweep exists to avoid; a run of narrow levels
    must not pay for the widest level elsewhere in the graph.)

    Parent slots follow the `padded_level_tables` convention — per child, the
    k-th slot is its k-th parent in ascending-id order — so the dense scan
    body tie-breaks identically."""
    R = stop - start
    R_pad = int(pad_run(R)) if pad_run is not None else R
    tasks = np.full((R_pad, width), -1, np.int32)
    par = np.full((R_pad, width, depth), -1, np.int32)
    pdat = np.zeros((R_pad, width, depth), np.float32)
    for r, lv in enumerate(range(start, stop)):
        t = segs.level_tasks(lv)
        es, ed, eg = segs.level_edges(lv)
        if len(t) > width:
            raise ValueError(f"level {lv} width {len(t)} exceeds {width}")
        tasks[r, : len(t)] = t
        if len(es) == 0:
            continue
        # within-segment position: edges are sorted by (slot, parent id)
        starts = np.zeros(len(es), np.int64)
        first = np.flatnonzero(np.diff(eg)) + 1
        starts[first] = first
        np.maximum.accumulate(starts, out=starts)
        k = np.arange(len(es)) - starts
        if int(k.max()) >= depth:
            raise ValueError(f"level {lv} fan-in {int(k.max()) + 1} exceeds {depth}")
        par[r, eg, k] = es
        pdat[r, eg, k] = ed
    return FusedDenseRun(tasks, par, pdat)


def stack_cost_planes(
    g: TaskGraph, comps: "Sequence[np.ndarray] | np.ndarray"
) -> np.ndarray:
    """Validate and stack per-scenario ``(v, P)`` cost planes into the
    float32 ``(B, v, P)`` array the batched device sweep runs on."""
    if not isinstance(comps, np.ndarray):
        comps = np.stack([np.asarray(c) for c in comps])
    comps = np.asarray(comps, np.float32)
    if comps.ndim != 3 or comps.shape[1] != g.n:
        raise ValueError(f"comps must be (B, {g.n}, P); got {comps.shape}")
    return comps


def csr_batch_segments(
    g: TaskGraph, comps: "Sequence[np.ndarray] | np.ndarray"
) -> tuple[LevelSegments, np.ndarray]:
    """Shared segment arrays + stacked per-scenario cost planes for the
    batched (vmapped) CSR sweep.

    The level/segment structure depends only on the graph, so one
    :class:`LevelSegments` is shared across the whole batch; the per-scenario
    cost planes are stacked via :func:`stack_cost_planes`.
    """
    return csr_level_segments(g), stack_cost_planes(g, comps)


def csr_level_segments(g: TaskGraph) -> LevelSegments:
    """Flatten each level's parent edges into contiguous segments.

    The parents-CSR is already ordered by (child, parent); a stable sort of
    edges by the child's level groups each level's edges contiguously while
    preserving that order, so within a level edges run over children in slot
    order with each child's parents in ascending-id order.
    """
    order, bounds = _level_order(g)
    slot = _slots_from_order(g, order, bounds)
    indeg = g.in_degree
    edst = np.repeat(np.arange(g.n, dtype=np.int64), indeg)
    eorder = np.argsort(g.level[edst], kind="stable")
    edge_bounds = np.searchsorted(g.level[edst][eorder], np.arange(g.n_levels + 1))
    return LevelSegments(
        task_ids=order.astype(np.int32),
        task_bounds=bounds.astype(np.int64),
        edge_src=g.pindices[eorder].astype(np.int32),
        edge_data=g.pdata[eorder],
        edge_seg=slot[edst[eorder]],
        edge_bounds=edge_bounds.astype(np.int64),
    )
