"""Comparison metrics (paper §7.3): makespan, speedup, SLR, slack."""
from __future__ import annotations

import numpy as np

from .ceft import min_comp_critical_path
from .machine import Machine
from .schedule import Schedule, sequential_time
from .taskgraph import TaskGraph


def speedup(sched: Schedule, comp: np.ndarray, m: Machine) -> float:
    """eq. 8: sequential time (best single processor for the whole graph)
    over makespan."""
    return sequential_time(comp, m) / sched.makespan


def slr(sched: Schedule, g: TaskGraph, comp: np.ndarray) -> float:
    """eq. 9: makespan normalized by the sum of minimum computation costs of
    the CP_MIN tasks (communication ignored) -- identical denominator for every
    algorithm, >= 1 for any valid schedule."""
    denom, _ = min_comp_critical_path(g, comp)
    return sched.makespan / denom


def slack(sched: Schedule, g: TaskGraph, comp: np.ndarray, m: Machine) -> float:
    """eq. 10: mean over tasks of M - b_level - t_level, computed with the
    *scheduled* assignment's execution and communication costs (robustness)."""
    ic = m.inst_class
    v = g.n
    w = comp[np.arange(v), ic[sched.proc]]
    t_level = np.zeros(v, np.float64)
    for i in range(v):
        for j, d in zip(g.children(i), g.child_data(i)):
            c = m.comm_inst(float(d), int(sched.proc[i]), int(sched.proc[j]))
            t_level[j] = max(t_level[j], t_level[i] + w[i] + c)
    b_level = np.zeros(v, np.float64)
    for i in range(v - 1, -1, -1):
        best = 0.0
        for j, d in zip(g.children(i), g.child_data(i)):
            c = m.comm_inst(float(d), int(sched.proc[i]), int(sched.proc[j]))
            best = max(best, c + b_level[j])
        b_level[i] = w[i] + best
    M = sched.makespan
    return float(np.mean(M - b_level - t_level))
