"""HEFT (Topcuoglu et al. 2002) and its rank-swapped variants (paper §8.2)."""
from __future__ import annotations

import numpy as np

from .machine import Machine
from .ranks import rank_ceft_down, rank_ceft_up, rank_d, rank_u
from .schedule import Schedule, list_schedule
from .taskgraph import TaskGraph


def heft(g: TaskGraph, comp: np.ndarray, m: Machine) -> Schedule:
    """Classic HEFT: upward-rank priority + insertion-based EFT placement."""
    return list_schedule(g, comp, m, priority=rank_u(g, comp, m))


def heft_down(g: TaskGraph, comp: np.ndarray, m: Machine) -> Schedule:
    """HEFT ordered by downward rank.  rank_d grows along the graph, so the
    ready-queue uses its negation to stay topologically consistent (entry
    tasks first)."""
    return list_schedule(g, comp, m, priority=-rank_d(g, comp, m))


def ceft_heft_up(g: TaskGraph, comp: np.ndarray, m: Machine) -> Schedule:
    """CEFT-HEFT-UP: HEFT with rank_ceft_up (CEFT on the transposed DAG)."""
    return list_schedule(g, comp, m, priority=rank_ceft_up(g, comp, m))


def ceft_heft_down(g: TaskGraph, comp: np.ndarray, m: Machine) -> Schedule:
    """CEFT-HEFT-DOWN: HEFT with rank_ceft_down (the CEFT DP array)."""
    return list_schedule(g, comp, m, priority=-rank_ceft_down(g, comp, m))
