"""CEFT — Critical Earliest Finish Time (paper §4, Algorithm 1).

    CEFT(t_i, p_j) = C_comp(t_i, p_j)
                   + max_{t_k in parents(t_i)} min_{p_l} [ CEFT(t_k, p_l)
                                                           + comm({t_k,p_l},{t_i,p_j}) ]

with comm zero when p_l == p_j (class view: co-location).  The critical path is
``max_{sinks} min_p CEFT(sink, p)`` and the DP carries predecessor pointers so the
(task -> processor-class) *partial assignment* of the path can be reconstructed
(paper lines 19-26; the frontier/backtrack bookkeeping realizes the O(beta*p)
space argument of §5).

Two implementations:
  * ``ceft_reference`` — the paper's Algorithm 1 verbatim (4 nested loops).
    This is the paper-faithful baseline recorded in EXPERIMENTS.md §Perf.
  * ``ceft`` — per-task vectorization over (p_l, p_j) (numpy).  Same results.
The fully level-vectorized JAX/Pallas formulation lives in ``ceft_jax.py``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .machine import Machine
from .taskgraph import TaskGraph

NEG = -np.inf


@dataclasses.dataclass
class CeftResult:
    ceft: np.ndarray        # (v, P) dynamic programming array
    pred_task: np.ndarray   # (v, P) maximizing parent t_k^max (-1 for sources)
    pred_proc: np.ndarray   # (v, P) that parent's minimizing class p_l^min
    sink: int               # t_s^max
    sink_proc: int          # p_s^min
    cpl: float              # critical-path length

    @property
    def path(self) -> list[tuple[int, int]]:
        """The critical path with its partial assignment, entry -> exit:
        list of (task, processor-class)."""
        out: list[tuple[int, int]] = []
        t, p = self.sink, self.sink_proc
        while t >= 0:
            out.append((int(t), int(p)))
            t, p = int(self.pred_task[t, p]), int(self.pred_proc[t, p])
        return out[::-1]

    @property
    def assignment(self) -> dict[int, int]:
        return dict(self.path)


def _finalize(g: TaskGraph, ceft, pred_task, pred_proc) -> CeftResult:
    """Paper lines 21-26: per sink minimize over classes, then maximize over
    sinks (the longest shortest finish)."""
    sinks = g.sinks
    per_sink_proc = np.argmin(ceft[sinks], axis=1)
    per_sink_cost = ceft[sinks, per_sink_proc]
    k = int(np.argmax(per_sink_cost))
    return CeftResult(
        ceft=ceft,
        pred_task=pred_task,
        pred_proc=pred_proc,
        sink=int(sinks[k]),
        sink_proc=int(per_sink_proc[k]),
        cpl=float(per_sink_cost[k]),
    )


def ceft_reference(g: TaskGraph, comp: np.ndarray, m: Machine) -> CeftResult:
    """Algorithm 1, literal form.  O(P^2 e) time.  comp is the (v, P) class-view
    execution-time matrix C_comp."""
    v, P = comp.shape
    ceft = np.zeros((v, P), np.float64)
    pred_task = np.full((v, P), -1, np.int32)
    pred_proc = np.full((v, P), -1, np.int32)
    for ti in range(v):  # vertex ids are topological
        parents = g.parents(ti)
        pdat = g.parent_data(ti)
        if parents.size == 0:
            ceft[ti, :] = comp[ti, :]  # source task: execution time alone
            continue
        for pj in range(P):
            best = NEG
            bt, bp = -1, -1
            for tk, data in zip(parents, pdat):
                # min over p_l of CEFT(t_k, p_l) + comm({t_k,p_l},{t_i,p_j})
                cur, arg = np.inf, -1
                for pl in range(P):
                    comm = 0.0 if pl == pj else m.L[pl] + data / m.bw[pl, pj]
                    c = ceft[tk, pl] + comm
                    if c < cur:
                        cur, arg = c, pl
                # max over parents of the minimized choices
                if cur > best:
                    best, bt, bp = cur, int(tk), arg
            ceft[ti, pj] = comp[ti, pj] + best
            pred_task[ti, pj] = bt
            pred_proc[ti, pj] = bp
    return _finalize(g, ceft, pred_task, pred_proc)


def ceft(g: TaskGraph, comp: np.ndarray, m: Machine) -> CeftResult:
    """Vectorized Algorithm 1: per task, the (parents x P_l x P_j) relaxation is
    one dense max-min-plus contraction."""
    v, P = comp.shape
    ceft_arr = np.zeros((v, P), np.float64)
    pred_task = np.full((v, P), -1, np.int32)
    pred_proc = np.full((v, P), -1, np.int32)
    off = ~np.eye(P, dtype=bool)
    for ti in range(v):
        parents = g.parents(ti)
        if parents.size == 0:
            ceft_arr[ti, :] = comp[ti, :]
            continue
        pdat = g.parent_data(ti)
        # cand[k, l, j] = CEFT(parent_k, l) + comm(l, j | data_k)
        # (identical arithmetic to ceft_reference so ties break the same way)
        comm = (m.L[:, None] + pdat[:, None, None] / m.bw) * off
        cand = ceft_arr[parents][:, :, None] + comm
        argl = cand.argmin(axis=1)                      # (k, j)
        minl = np.take_along_axis(cand, argl[:, None, :], 1)[:, 0, :]  # (k, j)
        argk = minl.argmax(axis=0)                      # (j,)
        ceft_arr[ti] = comp[ti] + minl[argk, np.arange(P)]
        pred_task[ti] = parents[argk]
        pred_proc[ti] = argl[argk, np.arange(P)]
    return _finalize(g, ceft_arr, pred_task, pred_proc)


def chain_cost(
    path: list[tuple[int, int]], g: TaskGraph, comp: np.ndarray, m: Machine
) -> float:
    """Exact cost of a (task, class) chain: sum of execution times plus class-view
    comm along consecutive edges.  CEFT's value equals this for its own path."""
    total = 0.0
    for idx, (t, p) in enumerate(path):
        total += float(comp[t, p])
        if idx + 1 < len(path):
            t2, p2 = path[idx + 1]
            ps = g.parents(t2)
            pos = np.nonzero(ps == t)[0]
            if pos.size == 0:
                raise ValueError(f"path edge {t}->{t2} not in graph")
            data = float(g.parent_data(t2)[pos[0]])
            total += m.comm_class(data, p, p2)
    return total


def min_comp_critical_path(g: TaskGraph, comp: np.ndarray) -> tuple[float, list[int]]:
    """The classical CP_MIN (Definition 4 / SLR denominator): longest path using
    per-task minimum computation cost, communication ignored."""
    w = comp.min(axis=1)
    dist = np.full(g.n, NEG)
    pred = np.full(g.n, -1, np.int64)
    dist[g.sources] = w[g.sources]
    for i in range(g.n):
        for j in g.children(i):
            nd = dist[i] + w[j]
            if nd > dist[j]:
                dist[j] = nd
                pred[j] = i
    snk = int(g.sinks[np.argmax(dist[g.sinks])])
    path = [snk]
    while pred[path[-1]] >= 0:
        path.append(int(pred[path[-1]]))
    return float(dist[snk]), path[::-1]


def averaged_critical_path(g: TaskGraph, comp: np.ndarray, m: Machine) -> tuple[float, list[int]]:
    """The CPOP-style estimated CP: longest path under instance-count-weighted
    mean computation costs and mean communication costs (paper §2's first
    'simplifying assumption', used as the comparison CP in §7/§8)."""
    wbar = m.mean_comp(comp)
    dist = np.full(g.n, NEG)
    pred = np.full(g.n, -1, np.int64)
    dist[g.sources] = wbar[g.sources]
    for i in range(g.n):
        cbar = m.mean_comm(g.child_data(i))
        for j, c in zip(g.children(i), np.atleast_1d(cbar)):
            nd = dist[i] + c + wbar[j]
            if nd > dist[j]:
                dist[j] = nd
                pred[j] = i
    snk = int(g.sinks[np.argmax(dist[g.sinks])])
    path = [snk]
    while pred[path[-1]] >= 0:
        path.append(int(pred[path[-1]]))
    return float(dist[snk]), path[::-1]
