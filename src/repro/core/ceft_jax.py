"""Level-vectorized CEFT in JAX (the TPU-native reformulation; DESIGN.md §2).

The paper's Algorithm 1 is a 4-deep scalar loop.  On TPU we sweep the DAG one
*topological level* at a time: a whole level's relaxation

    cand[w, k, l, j] = CEFT[par[w,k], l] + comm(l, j | data[w,k])
    CEFT[task_w, j]  = comp[task_w, j] + max_k min_l cand[w, k, l, j]

is a dense, batched max-min-plus contraction (a tropical matmul) -- exactly the
shape the MXU/VPU wants.  Two device formulations:

  * ``ceft_jax`` — the padded dense sweep: ``lax.scan`` over fixed-size
    (n_levels, Wmax, Dmax) level tables.  Simple, but its work is
    O(levels · Wmax · Dmax · P²): on irregular fan-in graphs that is
    overwhelmingly padding.
  * ``ceft_jax_csr`` — the edge-centric CSR sweep (ISSUE 3): per level, gather
    parent CEFT values per *edge*, form only (E_level, P, P) candidates, min
    over the parent class, then ``jax.ops.segment_max`` over each child's
    contiguous parent segment.  Total work O(e·P²) — the paper's §5 bound.
    Level shapes are padded to power-of-two buckets so the jitted per-level
    step compiles a bounded O(log) set of shapes across graphs instead of one
    trace per (n_levels, Wmax, Dmax, v) tuple.

``relax_fn`` plugs in the Pallas kernels (repro.kernels) in place of the XLA
contractions; all formulations compute identical values (tests assert this).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .ceft import CeftResult, _finalize
from .machine import Machine
from .taskgraph import TaskGraph, csr_level_segments, padded_level_tables

NEG = jnp.float32(-3.4e38)


def xla_relax(pv, pdata, validp, L, bw):
    """Reference relaxation in pure XLA.

    pv: (W, D, P) parent CEFT values; pdata: (W, D); validp: (W, D) bool;
    L: (P,), bw: (P, P).  Returns (maxk (W,P), argk (W,P), argl_sel (W,P)).
    """
    P = L.shape[0]
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)
    comm = (L[:, None] + pdata[..., None, None] / bw) * off       # (W,D,P,P)
    cand = pv[..., :, None] + comm                                 # (W,D,Pl,Pj)
    argl = jnp.argmin(cand, axis=2).astype(jnp.int32)              # (W,D,Pj)
    minl = jnp.min(cand, axis=2)                                   # (W,D,Pj)
    minl = jnp.where(validp[..., None], minl, NEG)
    argk = jnp.argmax(minl, axis=1).astype(jnp.int32)              # (W,Pj)
    maxk = jnp.max(minl, axis=1)                                   # (W,Pj)
    argl_sel = jnp.take_along_axis(argl, argk[:, None, :], axis=1)[:, 0, :]
    return maxk, argk, argl_sel


def _sweep_impl(tables, comp_pad, L, bw, relax: Callable = xla_relax):
    v = comp_pad.shape[0] - 1  # last row is the padding scratch slot
    P = comp_pad.shape[1]

    def body(carry, xs):
        ceft_arr, ptask, pproc = carry
        tasks, par, pdata = xs
        validt = tasks >= 0
        tt = jnp.where(validt, tasks, v)
        validp = par >= 0
        pp = jnp.where(validp, par, v)
        pv = ceft_arr[pp]                                          # (W,D,P)
        maxk, argk, argl_sel = relax(pv, pdata, validp, L, bw)
        has_par = validp.any(axis=1)
        relaxed = jnp.where(has_par[:, None], maxk, 0.0)
        newv = comp_pad[tt] + relaxed
        pt = jnp.take_along_axis(pp, argk, axis=1)                 # (W,P)
        pt = jnp.where(has_par[:, None], pt, -1)
        pl = jnp.where(has_par[:, None], argl_sel, -1)
        keep = validt[:, None]
        ceft_arr = ceft_arr.at[tt].set(jnp.where(keep, newv, ceft_arr[tt]))
        ptask = ptask.at[tt].set(jnp.where(keep, pt, ptask[tt]))
        pproc = pproc.at[tt].set(jnp.where(keep, pl, pproc[tt]))
        return (ceft_arr, ptask, pproc), None

    init = (
        jnp.zeros((v + 1, P), comp_pad.dtype),
        jnp.full((v + 1, P), -1, jnp.int32),
        jnp.full((v + 1, P), -1, jnp.int32),
    )
    (ceft_arr, ptask, pproc), _ = jax.lax.scan(body, init, tables)
    return ceft_arr[:v], ptask[:v], pproc[:v]


_sweep = jax.jit(_sweep_impl, static_argnames=("relax",))

# module-level cached vmapped sweep: building a fresh jax.vmap closure per
# ceft_jax_batch call forced a retrace each invocation (the straggler loop
# calls this repeatedly) -- one jitted callable retraces only on shape change
_sweep_batch = jax.jit(
    jax.vmap(_sweep_impl, in_axes=(None, 0, 0, 0)),
)


def device_inputs(g: TaskGraph, comp: np.ndarray, m: Machine, dtype=jnp.float32):
    t = padded_level_tables(g)
    tables = (
        jnp.asarray(t["tasks"]),
        jnp.asarray(t["par"]),
        jnp.asarray(t["pdata"], dtype),
    )
    comp_pad = jnp.concatenate(
        [jnp.asarray(comp, dtype), jnp.zeros((1, comp.shape[1]), dtype)], axis=0
    )
    return tables, comp_pad, jnp.asarray(m.L, dtype), jnp.asarray(m.bw, dtype)


def ceft_jax(
    g: TaskGraph, comp: np.ndarray, m: Machine, *, relax: Callable = xla_relax
) -> CeftResult:
    tables, comp_pad, L, bw = device_inputs(g, comp, m)
    ceft_arr, ptask, pproc = _sweep(tables, comp_pad, L, bw, relax=relax)
    return _finalize(
        g,
        np.asarray(ceft_arr, np.float64),
        np.asarray(ptask),
        np.asarray(pproc),
    )


def ceft_jax_batch(g: TaskGraph, comps: np.ndarray, Ls: np.ndarray, bws: np.ndarray):
    """vmap over machines that share P (batched re-planning / straggler sweeps).

    comps: (B, v, P); Ls: (B, P); bws: (B, P, P).  Returns the (B, v, P) CEFT
    arrays and predecessor tables (device arrays).
    """
    t = padded_level_tables(g)
    tables = (
        jnp.asarray(t["tasks"]),
        jnp.asarray(t["par"]),
        jnp.asarray(t["pdata"], jnp.float32),
    )
    pad = jnp.zeros((comps.shape[0], 1, comps.shape[2]), jnp.float32)
    comp_pad = jnp.concatenate([jnp.asarray(comps, jnp.float32), pad], axis=1)
    return _sweep_batch(
        tables, comp_pad, jnp.asarray(Ls, jnp.float32), jnp.asarray(bws, jnp.float32)
    )


# ------------------------------------------------------------ CSR / edge-centric
def xla_edge_relax(pv, pdata, L, bw):
    """Edge-centric relaxation: per-edge min over the parent class.

    pv: (E, P) gathered parent CEFT values; pdata: (E,); L: (P,); bw: (P, P).
    Returns (minl (E, P), argl (E, P) int32): for each edge and child class j,
    min_l pv[e, l] + comm(l, j | pdata[e]) and the arg-min class.
    """
    P = L.shape[0]
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)
    comm = (L[:, None] + pdata[:, None, None] / bw) * off          # (E,Pl,Pj)
    cand = pv[:, :, None] + comm                                    # (E,Pl,Pj)
    return jnp.min(cand, axis=1), jnp.argmin(cand, axis=1).astype(jnp.int32)


def _bucket(n: int, minimum: int = 8) -> int:
    """Smallest power of two >= n (and >= minimum): the jit-shape bucket."""
    b = minimum
    while b < n:
        b <<= 1
    return b


# trace counters, keyed by the traced shape tuple -- the bounded-compilation
# acceptance test reads these (tracing executes the Python body once per shape)
CSR_TRACES: dict[tuple, int] = {}


@functools.partial(
    jax.jit, donate_argnums=(0, 1, 2), static_argnames=("num_segments", "relax")
)
def _csr_level_step(
    ceft_arr,      # (v_b + 1, P) running DP table (donated; row v_b is scratch)
    ptask,         # (v_b + 1, P) int32 predecessor task (donated)
    pproc,         # (v_b + 1, P) int32 predecessor class (donated)
    comp_pad,      # (v_b + 1, P) execution times (scratch row zero)
    tasks,         # (W_b,)  int32 vertex ids, padded with v_b
    edge_src,      # (E_b,)  int32 parent vertex ids, padded with v_b
    edge_data,     # (E_b,)  data volume per edge (0 where padded)
    edge_seg,      # (E_b,)  int32 within-level child slot, padded with W_b - 1
    e_real,        # ()      int32 number of real edges (device scalar: no retrace)
    L, bw,
    *,
    num_segments: int,  # = W_b (static)
    relax: Callable = xla_edge_relax,
):
    """One level of the edge-centric CEFT sweep.

    Work is O(E_b · P²) with E_b the power-of-two edge bucket of this level;
    summed over levels that is O(e · P²) within a factor 2.  Called only for
    levels >= 1 (every real task there has >= 1 parent).
    """
    key = (ceft_arr.shape, tasks.shape, edge_src.shape, num_segments)
    CSR_TRACES[key] = CSR_TRACES.get(key, 0) + 1

    E_b = edge_src.shape[0]
    pv = ceft_arr[edge_src]                                        # (E,P) gather
    minl, argl = relax(pv, edge_data, L, bw)                       # (E,P) each
    valid = jnp.arange(E_b, dtype=jnp.int32) < e_real
    minl = jnp.where(valid[:, None], minl, NEG)
    # per-child max over its contiguous parent segment, first-max tie-break in
    # edge order (== ascending parent id, matching argmax over the dense table)
    maxk = jax.ops.segment_max(minl, edge_seg, num_segments=num_segments)
    is_first = jnp.where(
        valid[:, None] & (minl == maxk[edge_seg]),
        jnp.arange(E_b, dtype=jnp.int32)[:, None],
        jnp.int32(E_b),
    )
    arg_edge = jax.ops.segment_min(is_first, edge_seg, num_segments=num_segments)
    arg_edge = jnp.minimum(arg_edge, E_b - 1)                      # (W,P)
    P = L.shape[0]
    cols = jnp.arange(P, dtype=jnp.int32)[None, :]
    pt = edge_src[arg_edge].astype(jnp.int32)                      # (W,P)
    pl = argl[arg_edge, cols]                                      # (W,P)
    newv = comp_pad[tasks] + maxk
    ceft_arr = ceft_arr.at[tasks].set(newv, mode="drop")
    ptask = ptask.at[tasks].set(pt, mode="drop")
    pproc = pproc.at[tasks].set(pl, mode="drop")
    return ceft_arr, ptask, pproc


def csr_device_inputs(g: TaskGraph, comp: np.ndarray, m: Machine, dtype=jnp.float32):
    """Bucketed per-level device arrays for :func:`ceft_jax_csr`.

    Returns (levels, comp_pad, L, bw, v_b) where ``levels`` is a list of
    per-level tuples (tasks, edge_src, edge_data, edge_seg, e_real, W_b) with
    every array padded to power-of-two buckets, and comp_pad is the (v_b+1, P)
    execution-time table (vertex count bucketed too, so graph size does not
    leak into the jit key).
    """
    segs = csr_level_segments(g)
    v, P = comp.shape
    v_b = _bucket(v)
    comp_pad = np.zeros((v_b + 1, P), np.float32)
    comp_pad[:v] = comp
    levels = []
    for k in range(1, segs.n_levels):
        t = segs.level_tasks(k)
        esrc, edat, eseg = segs.level_edges(k)
        W_b = _bucket(len(t))
        E_b = _bucket(len(esrc), minimum=8)
        tasks = np.full(W_b, v_b, np.int32)
        tasks[: len(t)] = t
        src = np.full(E_b, v_b, np.int32)
        src[: len(esrc)] = esrc
        dat = np.zeros(E_b, np.float32)
        dat[: len(esrc)] = edat
        seg = np.full(E_b, W_b - 1, np.int32)
        seg[: len(esrc)] = eseg
        levels.append(
            (
                jnp.asarray(tasks),
                jnp.asarray(src),
                jnp.asarray(dat),
                jnp.asarray(seg),
                jnp.asarray(len(esrc), jnp.int32),
                W_b,
            )
        )
    return (
        levels,
        jnp.asarray(comp_pad, dtype),
        jnp.asarray(m.L, dtype),
        jnp.asarray(m.bw, dtype),
        v_b,
    )


def csr_sweep(g: TaskGraph, comp: np.ndarray, inputs, *, relax: Callable = xla_edge_relax):
    """Run the bucketed CSR sweep over prebuilt :func:`csr_device_inputs`.

    Re-buildable per call because the per-level step donates its carry buffers
    (the DP table is updated in place on device).  Returns the (v, P) device
    arrays (ceft, pred_task, pred_proc)."""
    levels, comp_pad, L, bw, v_b = inputs
    v, P = comp.shape
    # level 0 = sources: CEFT(src, j) = comp(src, j), no predecessors
    ceft0 = np.zeros((v_b + 1, P), np.float32)
    srcs = g.sources
    ceft0[srcs] = comp[srcs]
    ceft_arr = jnp.asarray(ceft0)
    ptask = jnp.full((v_b + 1, P), -1, jnp.int32)
    pproc = jnp.full((v_b + 1, P), -1, jnp.int32)
    for tasks, esrc, edat, eseg, e_real, W_b in levels:
        ceft_arr, ptask, pproc = _csr_level_step(
            ceft_arr, ptask, pproc, comp_pad, tasks, esrc, edat, eseg,
            e_real, L, bw, num_segments=W_b, relax=relax,
        )
    return ceft_arr[:v], ptask[:v], pproc[:v]


def ceft_jax_csr(
    g: TaskGraph, comp: np.ndarray, m: Machine, *, relax: Callable = xla_edge_relax
) -> CeftResult:
    """Edge-centric CSR CEFT sweep: O(e·P²) work, bucketed jit shapes.

    Produces values bit-identical to :func:`ceft_jax` (same float32 arithmetic
    per candidate, same tie-breaking) while doing only real-edge work.
    """
    inputs = csr_device_inputs(g, comp, m)
    ceft_arr, ptask, pproc = csr_sweep(g, comp, inputs, relax=relax)
    return _finalize(
        g,
        np.asarray(ceft_arr, np.float64),
        np.asarray(ptask),
        np.asarray(pproc),
    )
