"""Level-vectorized CEFT in JAX (the TPU-native reformulation; DESIGN.md §2).

The paper's Algorithm 1 is a 4-deep scalar loop.  On TPU we sweep the DAG one
*topological level* at a time: a whole level's relaxation

    cand[w, k, l, j] = CEFT[par[w,k], l] + comm(l, j | data[w,k])
    CEFT[task_w, j]  = comp[task_w, j] + max_k min_l cand[w, k, l, j]

is a dense, batched max-min-plus contraction (a tropical matmul) -- exactly the
shape the MXU/VPU wants.  Two device formulations:

  * ``ceft_jax`` — the padded dense sweep: ``lax.scan`` over fixed-size
    (n_levels, Wmax, Dmax) level tables.  Simple, but its work is
    O(levels · Wmax · Dmax · P²): on irregular fan-in graphs that is
    overwhelmingly padding.
  * ``ceft_jax_csr`` — the fused hybrid sweep (ISSUE 3 + 4): adjacent levels
    are fused into super-step runs, each ``lax.scan``ned in one dispatch
    (level-0 init folded into the first).  Per run the layout adapts: no
    within-level in-degree skew -> run-local dense (R, W, D) tables driven
    through the same body as ``ceft_jax``; skewed fan-in -> the edge-centric
    segment layout (gather parent CEFT values per *edge*, form only
    (E_level, P, P) candidates, min over the parent class, then
    ``jax.ops.segment_max`` over each child's contiguous parent segment —
    O(e·P²) total, the paper's §5 bound).  All shapes are bucketed so sweeps
    compile a bounded O(log) set of traces across graphs instead of one per
    (n_levels, Wmax, Dmax, v) tuple.
  * ``ceft_jax_batch_csr`` — the batched re-planning form (ISSUE 4): a
    module-level jitted vmap over cost planes / machines with the fused
    segment tables shared across the batch (the straggler loop's shape).

``relax_fn`` plugs in the Pallas kernels (repro.kernels) in place of the XLA
edge contraction (segment-layout runs; dense-layout runs use the XLA dense
relax); all formulations compute identical values (tests assert this).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .ceft import CeftResult, _finalize
from .machine import Machine
from .taskgraph import (
    TaskGraph,
    csr_level_segments,
    fuse_levels,
    fuse_levels_dense,
    padded_level_tables,
    stack_cost_planes,
)

NEG = jnp.float32(-3.4e38)


def xla_relax(pv, pdata, validp, L, bw):
    """Reference relaxation in pure XLA.

    pv: (W, D, P) parent CEFT values; pdata: (W, D); validp: (W, D) bool;
    L: (P,), bw: (P, P).  Returns (maxk (W,P), argk (W,P), argl_sel (W,P)).
    """
    P = L.shape[0]
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)
    comm = (L[:, None] + pdata[..., None, None] / bw) * off       # (W,D,P,P)
    cand = pv[..., :, None] + comm                                 # (W,D,Pl,Pj)
    argl = jnp.argmin(cand, axis=2).astype(jnp.int32)              # (W,D,Pj)
    minl = jnp.min(cand, axis=2)                                   # (W,D,Pj)
    minl = jnp.where(validp[..., None], minl, NEG)
    argk = jnp.argmax(minl, axis=1).astype(jnp.int32)              # (W,Pj)
    maxk = jnp.max(minl, axis=1)                                   # (W,Pj)
    argl_sel = jnp.take_along_axis(argl, argk[:, None, :], axis=1)[:, 0, :]
    return maxk, argk, argl_sel


def _dense_level_body(v: int, comp_pad, L, bw, relax: Callable):
    """The dense per-level scan body, shared verbatim by the whole-graph
    padded sweep (``_sweep``) and the run-local dense-layout super-steps
    (``_dense_superstep_impl``) so the two lower identically — the fused
    hybrid sweep stays bit-identical to ``ceft_jax`` by construction."""
    def body(carry, xs):
        ceft_arr, ptask, pproc = carry
        tasks, par, pdata = xs
        validt = tasks >= 0
        tt = jnp.where(validt, tasks, v)
        validp = par >= 0
        pp = jnp.where(validp, par, v)
        pv = ceft_arr[pp]                                          # (W,D,P)
        maxk, argk, argl_sel = relax(pv, pdata, validp, L, bw)
        has_par = validp.any(axis=1)
        relaxed = jnp.where(has_par[:, None], maxk, 0.0)
        newv = comp_pad[tt] + relaxed
        pt = jnp.take_along_axis(pp, argk, axis=1)                 # (W,P)
        pt = jnp.where(has_par[:, None], pt, -1)
        pl = jnp.where(has_par[:, None], argl_sel, -1)
        keep = validt[:, None]
        ceft_arr = ceft_arr.at[tt].set(jnp.where(keep, newv, ceft_arr[tt]))
        ptask = ptask.at[tt].set(jnp.where(keep, pt, ptask[tt]))
        pproc = pproc.at[tt].set(jnp.where(keep, pl, pproc[tt]))
        return (ceft_arr, ptask, pproc), None

    return body


def _sweep_impl(tables, comp_pad, L, bw, relax: Callable = xla_relax):
    v = comp_pad.shape[0] - 1  # last row is the padding scratch slot
    P = comp_pad.shape[1]
    body = _dense_level_body(v, comp_pad, L, bw, relax)
    init = (
        jnp.zeros((v + 1, P), comp_pad.dtype),
        jnp.full((v + 1, P), -1, jnp.int32),
        jnp.full((v + 1, P), -1, jnp.int32),
    )
    (ceft_arr, ptask, pproc), _ = jax.lax.scan(body, init, tables)
    return ceft_arr[:v], ptask[:v], pproc[:v]


_sweep = jax.jit(_sweep_impl, static_argnames=("relax",))

# module-level cached vmapped sweep: building a fresh jax.vmap closure per
# ceft_jax_batch call forced a retrace each invocation (the straggler loop
# calls this repeatedly) -- one jitted callable retraces only on shape change
_sweep_batch = jax.jit(
    jax.vmap(_sweep_impl, in_axes=(None, 0, 0, 0)),
)


def device_inputs(g: TaskGraph, comp: np.ndarray, m: Machine, dtype=jnp.float32):
    t = padded_level_tables(g)
    tables = (
        jnp.asarray(t["tasks"]),
        jnp.asarray(t["par"]),
        jnp.asarray(t["pdata"], dtype),
    )
    comp_pad = jnp.concatenate(
        [jnp.asarray(comp, dtype), jnp.zeros((1, comp.shape[1]), dtype)], axis=0
    )
    return tables, comp_pad, jnp.asarray(m.L, dtype), jnp.asarray(m.bw, dtype)


def ceft_jax(
    g: TaskGraph, comp: np.ndarray, m: Machine, *, relax: Callable = xla_relax
) -> CeftResult:
    tables, comp_pad, L, bw = device_inputs(g, comp, m)
    ceft_arr, ptask, pproc = _sweep(tables, comp_pad, L, bw, relax=relax)
    return _finalize(
        g,
        np.asarray(ceft_arr, np.float64),
        np.asarray(ptask),
        np.asarray(pproc),
    )


def ceft_jax_batch(g: TaskGraph, comps: np.ndarray, Ls: np.ndarray, bws: np.ndarray):
    """vmap over machines that share P (batched re-planning / straggler sweeps).

    comps: (B, v, P); Ls: (B, P); bws: (B, P, P).  Returns the (B, v, P) CEFT
    arrays and predecessor tables (device arrays).
    """
    t = padded_level_tables(g)
    tables = (
        jnp.asarray(t["tasks"]),
        jnp.asarray(t["par"]),
        jnp.asarray(t["pdata"], jnp.float32),
    )
    pad = jnp.zeros((comps.shape[0], 1, comps.shape[2]), jnp.float32)
    comp_pad = jnp.concatenate([jnp.asarray(comps, jnp.float32), pad], axis=1)
    return _sweep_batch(
        tables, comp_pad, jnp.asarray(Ls, jnp.float32), jnp.asarray(bws, jnp.float32)
    )


# ------------------------------------------------------------ CSR / edge-centric
def xla_edge_relax(pv, pdata, L, bw):
    """Edge-centric relaxation: per-edge min over the parent class.

    pv: (E, P) gathered parent CEFT values; pdata: (E,); L: (P,); bw: (P, P).
    Returns (minl (E, P), argl (E, P) int32): for each edge and child class j,
    min_l pv[e, l] + comm(l, j | pdata[e]) and the arg-min class.
    """
    P = L.shape[0]
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)
    comm = (L[:, None] + pdata[:, None, None] / bw) * off          # (E,Pl,Pj)
    cand = pv[:, :, None] + comm                                    # (E,Pl,Pj)
    return jnp.min(cand, axis=1), jnp.argmin(cand, axis=1).astype(jnp.int32)


# --- bucket policy (single owner: this module; ci.sh greps the invariant) ---
# fusion waste budget: adjacent levels fuse into one scanned super-step as
# long as the run's padded work (R · (W_b + E_b) at the run-max buckets) stays
# within this factor of the real work -- trading a little padded compute for
# far fewer dispatches (the Python-dispatch overhead is what made deep narrow
# graphs lose to the dense scan)
CSR_FUSE_WASTE = 4.0

# hybrid layout threshold: a fused run takes the dense (R, W, D) layout when
# its width·fan-in bucket is within this factor of its edge bucket (no
# within-level in-degree skew — chains, GE, layered DAGs); skewed runs (star
# fan-in, heavy tails) keep the O(e) segment layout
CSR_DENSE_SKEW = 1.5


def _geo_bucket(r: int) -> int:
    """The jit-shape bucket: the √2-spaced grid {1,2,3,4,6,8,12,16,24,...}.

    Still O(log) distinct values (bounded traces), but padding wastes <= 1/3
    extra work instead of pow2's almost-2x.  Used for every bucketed axis:
    vertex count, per-level width / edge cap, fan-in depth, source count,
    and fused run length."""
    b = 1
    while b < r:
        if b < 2:
            b = 2
        elif (b & (b - 1)) == 0:  # pow2 -> pow2 * 1.5
            b += b // 2
        else:                     # pow2 * 1.5 -> next pow2
            b = (b // 3) * 4
    return b


# trace counters, keyed by the traced shape tuple -- the bounded-compilation
# acceptance test reads these (tracing executes the Python body once per shape)
CSR_TRACES: dict[tuple, int] = {}


def _superstep_impl(
    ceft_arr,      # (v_b + 1, P) running DP table (donated; row v_b is scratch)
    ptask,         # (v_b + 1, P) int32 predecessor task (donated)
    pproc,         # (v_b + 1, P) int32 predecessor class (donated)
    comp_pad,      # (v_b + 1, P) execution times (scratch row zero)
    tasks,         # (R, W_b) int32 vertex ids, padded with v_b
    edge_src,      # (R, E_b) int32 parent vertex ids, padded with v_b
    edge_data,     # (R, E_b) data volume per edge (0 where padded)
    edge_seg,      # (R, E_b) int32 within-level child slot, padded with W_b - 1
    e_real,        # (R,)     int32 real edges per level (device array: no retrace)
    L, bw,
    *,
    relax: Callable = xla_edge_relax,
    tag: str = "csr",
    masked: bool = True,
):
    """One fused super-step of the edge-centric CEFT sweep: ``lax.scan`` over
    a run of R adjacent levels sharing one (W_b, E_b) padded shape, in ONE
    dispatch.

    Per level the work is O(E_b · P²); summed over a sweep's runs that is
    O(e · P²) within the CSR_FUSE_WASTE factor (the paper's §5 bound).
    Levels inside a run depend on each other through the carried DP table,
    exactly as the per-level formulation did — the scan only removes the
    Python-level dispatch per level, not the sequential dependence.  No-op
    padding levels (``e_real == 0``, all-padding tasks) write only the
    scratch row v_b.

    ``masked`` is False when no *real* level in the run carries padded edges
    (0 < e_real < E_b never happens): the NEG-masking then folds away.  No-op
    levels stay safe unmasked — all their ids are the scratch row, so they
    compute garbage into scratch and touch nothing real.
    """
    key = (tag, masked, ceft_arr.shape, tasks.shape, edge_src.shape)
    CSR_TRACES[key] = CSR_TRACES.get(key, 0) + 1
    W_b = tasks.shape[-1]
    E_b = edge_src.shape[-1]
    P = L.shape[0]

    def body(carry, xs):
        ceft_arr, ptask, pproc = carry
        tasks, edge_src, edge_data, edge_seg, e_real = xs
        pv = ceft_arr[edge_src]                                    # (E,P) gather
        minl, argl = relax(pv, edge_data, L, bw)                   # (E,P) each
        if masked:
            valid = jnp.arange(E_b, dtype=jnp.int32) < e_real
            minl = jnp.where(valid[:, None], minl, NEG)
        cols = jnp.arange(P, dtype=jnp.int32)[None, :]
        # per-child max over its contiguous parent segment, first-max tie-break
        # in edge order (== ascending parent id, matching the dense argmax)
        if W_b == 1:
            # single segment (deep narrow runs: chains, GE tails) -- the
            # segmented reduction collapses to a plain max/argmax, whose
            # first-max tie-break equals first-max-in-edge-order
            maxk = jnp.max(minl, axis=0, keepdims=True)            # (1,P)
            arg_edge = jnp.argmax(minl, axis=0)[None, :]           # (1,P)
        else:
            maxk = jax.ops.segment_max(minl, edge_seg, num_segments=W_b)
            hit = minl == maxk[edge_seg]
            if masked:
                hit &= valid[:, None]
            is_first = jnp.where(
                hit,
                jnp.arange(E_b, dtype=jnp.int32)[:, None],
                jnp.int32(E_b),
            )
            arg_edge = jax.ops.segment_min(is_first, edge_seg, num_segments=W_b)
            arg_edge = jnp.minimum(arg_edge, E_b - 1)              # (W,P)
        pt = edge_src[arg_edge].astype(jnp.int32)                  # (W,P)
        pl = argl[arg_edge, cols]                                  # (W,P)
        newv = comp_pad[tasks] + maxk
        ceft_arr = ceft_arr.at[tasks].set(newv, mode="drop")
        ptask = ptask.at[tasks].set(pt, mode="drop")
        pproc = pproc.at[tasks].set(pl, mode="drop")
        return (ceft_arr, ptask, pproc), None

    carry, _ = jax.lax.scan(
        body, (ceft_arr, ptask, pproc),
        (tasks, edge_src, edge_data, edge_seg, e_real),
    )
    return carry


def _superstep_init_impl(
    comp_pad, srcs_pad, tasks, edge_src, edge_data, edge_seg, e_real, L, bw,
    *, relax: Callable = xla_edge_relax, tag: str = "csr", masked: bool = True,
):
    """First super-step of a sweep with the level-0 init folded in: a whole
    deep-chain sweep is then ONE dispatch, matching the dense scan's."""
    carry = _init_impl(comp_pad, srcs_pad, tag=tag + "+init")
    return _superstep_impl(
        *carry, comp_pad, tasks, edge_src, edge_data, edge_seg, e_real, L, bw,
        relax=relax, tag=tag, masked=masked,
    )


def _dense_superstep_impl(
    ceft_arr, ptask, pproc, comp_pad,
    tasks,   # (R, W_b) int32 vertex ids, -1 padded
    par,     # (R, W_b, D_b) int32 parent ids, -1 padded
    pdata,   # (R, W_b, D_b) data volume per parent edge
    L, bw,
    *, relax: Callable = xla_relax, tag: str = "csr_dense",
):
    """Dense-layout super-step: the run's levels scanned through the same
    per-level body as the whole-graph padded sweep, but over *run-local*
    (W_b, D_b) buckets.  The hybrid sweep picks this for runs without
    within-level in-degree skew (W·D ≈ E), where the dense contraction beats
    the segmented reduction; the work bound is preserved because the buckets
    are the run's own, not the graph-global (Wmax, Dmax)."""
    key = (tag, ceft_arr.shape, tasks.shape, par.shape)
    CSR_TRACES[key] = CSR_TRACES.get(key, 0) + 1
    v = comp_pad.shape[0] - 1
    body = _dense_level_body(v, comp_pad, L, bw, relax)
    carry, _ = jax.lax.scan(body, (ceft_arr, ptask, pproc), (tasks, par, pdata))
    return carry


def _dense_superstep_init_impl(
    comp_pad, srcs_pad, tasks, par, pdata, L, bw,
    *, relax: Callable = xla_relax, tag: str = "csr_dense",
):
    carry = _init_impl(comp_pad, srcs_pad, tag=tag + "+init")
    return _dense_superstep_impl(
        *carry, comp_pad, tasks, par, pdata, L, bw, relax=relax, tag=tag
    )


def _superstep_fns(relax: Callable, keep: bool = False):
    """Module-level cached jitted super-steps for one edge relax_fn, keyed
    (batched, layout, masked, with_init) with layout in {"seg", "dense"}.
    Dense-layout runs always use the XLA dense relax (a custom ``relax``
    plugs into the segment layout only).  Carry buffers are donated off-CPU —
    the DP table then updates in place; on CPU donation is unsupported and
    each donated call pays a fallback copy, so it is disabled there.

    ``keep=True`` selects non-donating variants even off-CPU: a sweep that
    snapshots its per-run carries for later resume (the plan cache's dirty-
    frontier path) must not hand those snapshots to a donating dispatch, or
    the cached buffers would be invalidated in place.  On CPU donation is
    already off, so keep is normalized away and the same compiled closures
    serve both paths (no extra traces).

    The backend is read per *call*, not once at closure-build time: the cache
    is keyed (relax, backend, keep), so a backend selected after the first
    sweep (tests forcing CPU, a GPU picked up mid-process) gets its own
    jitted closures with the right donation policy instead of inheriting
    whichever backend happened to be default first (ISSUE 5 regression)."""
    backend = jax.default_backend()
    if backend == "cpu":
        keep = False  # donation already disabled: one closure set for both
    return _superstep_fns_for(relax, backend, keep)


@functools.lru_cache(maxsize=None)
def _superstep_fns_for(relax: Callable, backend: str, keep: bool = False):
    donate = () if (backend == "cpu" or keep) else (0, 1, 2)
    fns = {}
    for batched in (False, True):
        tag = "csr_batch" if batched else "csr"
        for masked in (False, True):
            cont = functools.partial(
                _superstep_impl, relax=relax, masked=masked, tag=tag
            )
            init = functools.partial(
                _superstep_init_impl, relax=relax, masked=masked, tag=tag
            )
            if batched:
                cont = jax.vmap(
                    cont,
                    in_axes=(0, 0, 0, 0, None, None, None, None, None, 0, 0),
                )
                init = jax.vmap(
                    init, in_axes=(0, None, None, None, None, None, None, 0, 0)
                )
            fns[(batched, "seg", masked, False)] = jax.jit(
                cont, donate_argnums=donate
            )
            fns[(batched, "seg", masked, True)] = jax.jit(init)
        dtag = tag + "_dense" if batched else "csr_dense"
        dcont = functools.partial(_dense_superstep_impl, tag=dtag)
        dinit = functools.partial(_dense_superstep_init_impl, tag=dtag)
        if batched:
            dcont = jax.vmap(
                dcont, in_axes=(0, 0, 0, 0, None, None, None, 0, 0)
            )
            dinit = jax.vmap(dinit, in_axes=(0, None, None, None, None, 0, 0))
        fns[(batched, "dense", False, False)] = jax.jit(
            dcont, donate_argnums=donate
        )
        fns[(batched, "dense", False, True)] = jax.jit(dinit)
    fns["donate"] = donate  # introspectable: tests assert the policy matches
    return fns


def _init_impl(comp_pad, srcs_pad, *, tag: str = "init"):
    """Jitted sweep prologue — level 0: CEFT(src, j) = comp(src, j), no
    predecessors.  ``srcs_pad`` is the source-id list padded with the scratch
    row v_b (whose comp row is zero, so padded writes are no-ops).  Keeping
    the init on device, bucketed, makes a whole deep-chain sweep two
    dispatches (init + one scanned super-step) instead of host-built
    transfers per call."""
    key = (tag, comp_pad.shape, srcs_pad.shape)
    CSR_TRACES[key] = CSR_TRACES.get(key, 0) + 1
    v1, P = comp_pad.shape
    ceft0 = jnp.zeros((v1, P), comp_pad.dtype).at[srcs_pad].set(
        comp_pad[srcs_pad]
    )
    return (
        ceft0,
        jnp.full((v1, P), -1, jnp.int32),
        jnp.full((v1, P), -1, jnp.int32),
    )


_csr_init = jax.jit(_init_impl)
_csr_init_batch = jax.jit(
    jax.vmap(
        functools.partial(_init_impl, tag="init_batch"), in_axes=(0, None)
    )
)


def _fused_runs(g: TaskGraph, segs=None):
    """Host-side bucketed super-step tables — the bucket policy lives here,
    not in taskgraph.

    Greedy fusion: extend each run of adjacent levels while the padded work
    at the run-max buckets stays within CSR_FUSE_WASTE of the real work.
    Per-run *layout* choice: runs whose width·fan-in bucket is within
    CSR_DENSE_SKEW of the edge bucket (no within-level in-degree skew:
    chains, GE, layered DAGs) take the dense (R, W, D) layout built from
    run-local buckets (``fuse_levels_dense``); skewed runs (star fan-in,
    heavy tails) keep the segment layout (``fuse_levels``).  All shape axes
    use the √2 ``_geo_bucket`` grid and run lengths are padded with no-op
    levels, so neither depth nor exact widths leak into the jit key.
    Returns (runs, v_b, spans) with runs a level-ordered list of
    FusedLevelRun / FusedDenseRun and spans the aligned [lo, hi) level range
    of each run (level 0, the folded init, belongs to no run) — the dirty
    frontier of an incremental re-sweep resolves to a run through spans."""
    if segs is None:
        segs = csr_level_segments(g)
    v_b = _geo_bucket(g.n)
    tb, eb = segs.task_bounds, segs.edge_bounds
    ws = [int(tb[k + 1] - tb[k]) for k in range(1, segs.n_levels)]
    es = [int(eb[k + 1] - eb[k]) for k in range(1, segs.n_levels)]
    groups: list[tuple[int, int, int, int]] = []  # (lo, hi, W_b, E_b), levels [lo, hi)
    start = 0
    cur_w = cur_e = real = 0
    for k in range(len(ws)):
        if k == start:
            cur_w, cur_e = _geo_bucket(ws[k]), _geo_bucket(es[k])
            real = ws[k] + es[k]
            continue
        new_w = max(cur_w, _geo_bucket(ws[k]))
        new_e = max(cur_e, _geo_bucket(es[k]))
        r = k - start + 1
        if r * (new_w + new_e) <= CSR_FUSE_WASTE * (real + ws[k] + es[k]):
            cur_w, cur_e = new_w, new_e
            real += ws[k] + es[k]
        else:  # close the run: waste budget exceeded
            groups.append((start + 1, k + 1, cur_w, cur_e))
            start = k
            cur_w, cur_e = _geo_bucket(ws[k]), _geo_bucket(es[k])
            real = ws[k] + es[k]
    if len(ws) > start:
        groups.append((start + 1, len(ws) + 1, cur_w, cur_e))

    indeg = g.in_degree
    widths = [0] * len(ws)
    ecaps = [0] * len(ws)
    run_ids = [-1] * len(ws)
    layouts = []
    for i, (lo, hi, W_b, E_b) in enumerate(groups):
        run_tasks = segs.task_ids[tb[lo] : tb[hi]]
        D_b = _geo_bucket(int(indeg[run_tasks].max()))
        if W_b * D_b <= CSR_DENSE_SKEW * E_b:
            layouts.append(("dense", lo, hi, W_b, D_b))
        else:
            layouts.append(("seg", lo, hi))
            for k in range(lo - 1, hi - 1):
                widths[k], ecaps[k], run_ids[k] = W_b, E_b, i
    seg_runs = iter(
        fuse_levels(segs, widths, ecaps, pad_vertex=v_b,
                    pad_run=_geo_bucket, run_ids=run_ids)
    )
    runs = []
    spans = []
    for lay in layouts:
        if lay[0] == "dense":
            _, lo, hi, W_b, D_b = lay
            runs.append(fuse_levels_dense(
                segs, lo, hi, W_b, D_b, pad_run=_geo_bucket))
        else:
            _, lo, hi = lay
            runs.append(next(seg_runs))
        spans.append((lo, hi))
    return runs, v_b, tuple(spans)


def _device_runs(runs):
    """Move fused super-step tables to device (the scanned xs arrays), each
    tagged with its layout.  Segment runs carry the host-known ``masked``
    flag: False when no real level has padded edges (no-op run-padding
    levels are safe unmasked — they only touch the scratch row)."""
    out = []
    for r in runs:
        if hasattr(r, "par"):  # FusedDenseRun
            out.append(
                ("dense", jnp.asarray(r.tasks), jnp.asarray(r.par),
                 jnp.asarray(r.pdata))
            )
        else:
            E_b = r.edge_src.shape[-1]
            masked = bool(np.any((r.e_real > 0) & (r.e_real < E_b)))
            out.append(
                ("seg", jnp.asarray(r.tasks), jnp.asarray(r.edge_src),
                 jnp.asarray(r.edge_data), jnp.asarray(r.edge_seg),
                 jnp.asarray(r.e_real), masked)
            )
    return out


def _padded_sources(g: TaskGraph, v_b: int) -> np.ndarray:
    """Source ids padded with the scratch row v_b to a bucketed length (so
    the jitted init does not retrace per source count)."""
    srcs = g.sources
    s_b = _geo_bucket(len(srcs))
    out = np.full(s_b, v_b, np.int32)
    out[: len(srcs)] = srcs
    return out


def _build_device_state(g: TaskGraph, segs=None):
    """Uncached build of a graph's device-side sweep state: (device runs,
    padded sources, v_b, run level spans).  The *store* for this state lives
    in :mod:`repro.sched.plancache` (the unified plan cache, PR 6); this
    module only knows how to build it — callers go through
    :func:`_graph_device_state` so repeated sweeps of one graph hit the
    cache."""
    fused, v_b, spans = _fused_runs(g, segs=segs)
    runs = _device_runs(fused)
    srcs = jnp.asarray(_padded_sources(g, v_b))
    return runs, srcs, v_b, spans


def _graph_device_state(g: TaskGraph, segs=None):
    """(device runs, padded sources, v_b) for one graph — a thin view over
    the plan cache's identity-keyed device-state store."""
    from ..sched import plancache

    runs, srcs, v_b, _spans = plancache.device_state(g, segs=segs)
    return runs, srcs, v_b


def csr_device_inputs(g: TaskGraph, comp: np.ndarray, m: Machine, dtype=jnp.float32):
    """Bucketed fused super-step device arrays for :func:`ceft_jax_csr`.

    Returns (runs, comp_pad, srcs_pad, L, bw, v_b) where ``runs`` is a list
    of stacked per-run tuples (tasks, edge_src, edge_data, edge_seg, e_real)
    — one scanned dispatch each — and comp_pad is the (v_b+1, P)
    execution-time table (vertex count bucketed too, so graph size does not
    leak into the jit key).
    """
    runs, srcs_pad, v_b = _graph_device_state(g)
    v, P = comp.shape
    comp_pad = np.zeros((v_b + 1, P), np.float32)
    comp_pad[:v] = comp
    return (
        runs,
        jnp.asarray(comp_pad, dtype),
        srcs_pad,
        jnp.asarray(m.L, dtype),
        jnp.asarray(m.bw, dtype),
        v_b,
    )


def csr_sweep(
    inputs, *, relax: Callable = xla_edge_relax,
    keep_carries: list | None = None,
    resume: tuple | None = None,
):
    """Run the fused CSR sweep over prebuilt :func:`csr_device_inputs`
    (which carries everything the sweep needs -- no graph/cost re-reads, so
    stale-argument mismatches are impossible by construction).

    One jitted dispatch for the init plus one per fused run (a 64-level chain
    is TWO dispatches, not 64+).  Re-runnable per call because the super-step
    donates its carry buffers (the DP table is updated in place on device).
    Returns the *padded* (v_b+1, P) device arrays (ceft, pred_task,
    pred_proc); rows >= g.n are scratch — slice after the host transfer
    (slicing on device would add a per-call dispatch per output).

    Incremental re-sweep hooks (the plan cache's dirty-frontier path):

    * ``keep_carries`` — a list the sweep appends each executed run's output
      carry to.  The carry after run r-1 depends only on comp rows of levels
      below run r (levels are longest-path depth, so each vertex is written
      exactly once, in its own run), which is what makes run-granular resume
      bit-identical to a full sweep.
    * ``resume=(start, carry)`` — skip runs ``< start`` and continue from the
      snapshot ``carry`` (the keep_carries entry for run start-1) with the
      *current* comp_pad.  Rows for vertices in runs >= start are unwritten
      init state in the snapshot and are fully recomputed, so the result is
      bit-identical to a from-scratch sweep.  The caller guarantees no
      changed comp row lies below run start (level 0 or run 0 dirty => full
      sweep, there is no cheaper prefix to keep).

    Either hook switches to the non-donating keep fns so snapshots are never
    invalidated in place; the resumed runs reuse the exact per-run tables (and
    thus the exact ``_geo_bucket``-bucketed shapes) of the full sweep, so no
    new jit traces are minted by resuming."""
    runs, comp_pad, srcs_pad, L, bw, v_b = inputs
    keep = keep_carries is not None or resume is not None
    fns = _superstep_fns(relax, keep=keep)
    start, carry = resume if resume is not None else (0, None)
    for r in range(start, len(runs)):
        layout, *arrs = runs[r]
        masked = arrs.pop() if layout == "seg" else False
        if carry is None:  # level-0 init folded into the first dispatch
            carry = fns[(False, layout, masked, True)](
                comp_pad, srcs_pad, *arrs, L, bw
            )
        else:
            carry = fns[(False, layout, masked, False)](
                *carry, comp_pad, *arrs, L, bw
            )
        if keep_carries is not None:
            keep_carries.append(carry)
    if carry is None:  # single-level graph: no relaxation levels at all
        carry = _csr_init(comp_pad, srcs_pad)
    return carry


def ceft_jax_csr(
    g: TaskGraph, comp: np.ndarray, m: Machine, *, relax: Callable = xla_edge_relax
) -> CeftResult:
    """Edge-centric CSR CEFT sweep: O(e·P²) work, bucketed jit shapes, fused
    same-bucket super-steps.

    Produces values bit-identical to :func:`ceft_jax` (same float32 arithmetic
    per candidate, same tie-breaking) while doing only real-edge work.
    """
    v = g.n
    inputs = csr_device_inputs(g, comp, m)
    ceft_arr, ptask, pproc = csr_sweep(inputs, relax=relax)
    return _finalize(
        g,
        np.asarray(ceft_arr, np.float64)[:v],
        np.asarray(ptask)[:v],
        np.asarray(pproc)[:v],
    )


# ------------------------------------------------------- batched CSR re-planning
def csr_batch_device_inputs(g: TaskGraph, comps, Ls, bws, dtype=jnp.float32):
    """Device arrays for :func:`csr_batch_sweep`: the fused segment tables are
    shared (batch-invariant); cost planes / machines are stacked per scenario.

    Returns (runs, comp_pad (B, v_b+1, P), srcs_pad, Ls (B, P),
    bws (B, P, P), v_b)."""
    # hot re-planning path (same graph object): the plan cache's identity-
    # keyed device-state store makes the shared-segment rebuild a hit, only
    # the cost planes change per call
    comps = stack_cost_planes(g, comps)
    runs, srcs_pad, v_b = _graph_device_state(g)
    B, v, P = comps.shape
    comp_pad = np.zeros((B, v_b + 1, P), np.float32)
    comp_pad[:, :v] = comps
    return (
        runs,
        jnp.asarray(comp_pad, dtype),
        srcs_pad,
        jnp.asarray(np.asarray(Ls, np.float32), dtype),
        jnp.asarray(np.asarray(bws, np.float32), dtype),
        v_b,
    )


def csr_batch_sweep(inputs, *, relax: Callable = xla_edge_relax):
    """Run the batched fused CSR sweep over prebuilt
    :func:`csr_batch_device_inputs` (self-contained, like :func:`csr_sweep`): a module-level jitted vmap over the
    scenario axis with the segment tables passed unbatched (in_axes=None).
    Returns the *padded* (B, v_b+1, P) device arrays (ceft, pred_task,
    pred_proc); rows >= g.n are scratch (see :func:`csr_sweep`)."""
    runs, comp_pad, srcs_pad, Ls, bws, v_b = inputs
    fns = _superstep_fns(relax)
    carry = None
    for layout, *arrs in runs:
        masked = arrs.pop() if layout == "seg" else False
        if carry is None:  # level-0 init folded into the first dispatch
            carry = fns[(True, layout, masked, True)](
                comp_pad, srcs_pad, *arrs, Ls, bws
            )
        else:
            carry = fns[(True, layout, masked, False)](
                *carry, comp_pad, *arrs, Ls, bws
            )
    if carry is None:  # single-level graph: no relaxation levels at all
        carry = _csr_init_batch(comp_pad, srcs_pad)
    return carry


def ceft_jax_batch_csr(
    g: TaskGraph, comps: np.ndarray, Ls: np.ndarray, bws: np.ndarray,
    *, relax: Callable = xla_edge_relax,
):
    """Batched re-planning on the CSR formulation: vmap over machines that
    share P, segment tables shared across the batch (ISSUE 4 — the straggler
    loop's O(e·P²) bound).

    comps: (B, v, P); Ls: (B, P); bws: (B, P, P).  Returns the (B, v, P)
    arrays (host-sliced from the padded carries), bit-identical to
    :func:`ceft_jax_batch`.
    """
    v = g.n
    inputs = csr_batch_device_inputs(g, comps, Ls, bws)
    ceft_arr, ptask, pproc = csr_batch_sweep(inputs, relax=relax)
    return (
        np.asarray(ceft_arr)[:, :v],
        np.asarray(ptask)[:, :v],
        np.asarray(pproc)[:, :v],
    )


def ceft_batch_csr_results(
    g: TaskGraph, comps: np.ndarray, Ls: np.ndarray, bws: np.ndarray,
    *, relax: Callable = xla_edge_relax,
) -> list[CeftResult]:
    """Finalized :class:`CeftResult` per batched scenario (paper lines 19-26
    applied to each plane) — the form the re-planning schedulers consume."""
    ceft_arr, ptask, pproc = ceft_jax_batch_csr(g, comps, Ls, bws, relax=relax)
    ceft_np = np.asarray(ceft_arr, np.float64)
    pt_np, pp_np = np.asarray(ptask), np.asarray(pproc)
    return [
        _finalize(g, ceft_np[b], pt_np[b], pp_np[b]) for b in range(ceft_np.shape[0])
    ]


# ------------------------------------------------------ in-memory request DAGs
def request_graph(n: int, src, dst, data) -> TaskGraph:
    """TaskGraph for an in-memory request DAG — a thin view over the plan
    cache's content-keyed graph store: structurally-equal edge arrays map to
    the SAME TaskGraph object, so the identity-keyed device-state store hits
    and the fused segment tables are not rebuilt per tick.

    ``src``/``dst`` must already be topological (src < dst), the natural
    shape for prefill->decode chains.  A steady-state router whose pending
    mix keeps the same DAG structure across ticks pays the host-side
    segment/fusion build exactly once."""
    from ..sched import plancache

    return plancache.graph_for(n, src, dst, data)


def plan_request_dag(
    n: int, src, dst, data, comp: np.ndarray, m: Machine,
    *, relax: Callable = xla_edge_relax,
) -> CeftResult:
    """Plan one in-memory request DAG through the fused CSR sweep.

    The public entry point for online dispatchers (repro.serve.router): edge
    arrays in, mapped critical path out, without the caller owning TaskGraph
    construction or the device-state caching."""
    return ceft_jax_csr(request_graph(n, src, dst, data), comp, m, relax=relax)


def plan_request_dags(
    n: int, src, dst, data, comps: np.ndarray, Ls: np.ndarray, bws: np.ndarray,
    *, relax: Callable = xla_edge_relax,
) -> list[CeftResult]:
    """Batched scenario planning over one request DAG (nominal + degraded
    cost planes in a single vmapped dispatch — the straggler loop's shape,
    reused by the router when a degraded engine must shed work)."""
    return ceft_batch_csr_results(
        request_graph(n, src, dst, data), comps, Ls, bws, relax=relax
    )
