"""Level-vectorized CEFT in JAX (the TPU-native reformulation; DESIGN.md §2).

The paper's Algorithm 1 is a 4-deep scalar loop.  On TPU we sweep the DAG one
*topological level* at a time: a whole level's relaxation

    cand[w, k, l, j] = CEFT[par[w,k], l] + comm(l, j | data[w,k])
    CEFT[task_w, j]  = comp[task_w, j] + max_k min_l cand[w, k, l, j]

is a dense, batched max-min-plus contraction (a tropical matmul) -- exactly the
shape the MXU/VPU wants.  ``lax.scan`` runs over fixed-size padded level tables
so the whole sweep jits once per table shape; predecessor argmin/argmax indices
are carried so the host can backtrack the path + partial assignment.

``relax_fn`` plugs in the Pallas kernel (repro.kernels.ceft_relax) in place of
the XLA contraction; both compute identical values (tests assert this).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .ceft import CeftResult, _finalize
from .machine import Machine
from .taskgraph import TaskGraph, padded_level_tables

NEG = jnp.float32(-3.4e38)


def xla_relax(pv, pdata, validp, L, bw):
    """Reference relaxation in pure XLA.

    pv: (W, D, P) parent CEFT values; pdata: (W, D); validp: (W, D) bool;
    L: (P,), bw: (P, P).  Returns (maxk (W,P), argk (W,P), argl_sel (W,P)).
    """
    P = L.shape[0]
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)
    comm = (L[:, None] + pdata[..., None, None] / bw) * off       # (W,D,P,P)
    cand = pv[..., :, None] + comm                                 # (W,D,Pl,Pj)
    argl = jnp.argmin(cand, axis=2).astype(jnp.int32)              # (W,D,Pj)
    minl = jnp.min(cand, axis=2)                                   # (W,D,Pj)
    minl = jnp.where(validp[..., None], minl, NEG)
    argk = jnp.argmax(minl, axis=1).astype(jnp.int32)              # (W,Pj)
    maxk = jnp.max(minl, axis=1)                                   # (W,Pj)
    argl_sel = jnp.take_along_axis(argl, argk[:, None, :], axis=1)[:, 0, :]
    return maxk, argk, argl_sel


@functools.partial(jax.jit, static_argnames=("relax",))
def _sweep(tables, comp_pad, L, bw, relax: Callable = xla_relax):
    v = comp_pad.shape[0] - 1  # last row is the padding scratch slot
    P = comp_pad.shape[1]

    def body(carry, xs):
        ceft_arr, ptask, pproc = carry
        tasks, par, pdata = xs
        validt = tasks >= 0
        tt = jnp.where(validt, tasks, v)
        validp = par >= 0
        pp = jnp.where(validp, par, v)
        pv = ceft_arr[pp]                                          # (W,D,P)
        maxk, argk, argl_sel = relax(pv, pdata, validp, L, bw)
        has_par = validp.any(axis=1)
        relaxed = jnp.where(has_par[:, None], maxk, 0.0)
        newv = comp_pad[tt] + relaxed
        pt = jnp.take_along_axis(pp, argk, axis=1)                 # (W,P)
        pt = jnp.where(has_par[:, None], pt, -1)
        pl = jnp.where(has_par[:, None], argl_sel, -1)
        keep = validt[:, None]
        ceft_arr = ceft_arr.at[tt].set(jnp.where(keep, newv, ceft_arr[tt]))
        ptask = ptask.at[tt].set(jnp.where(keep, pt, ptask[tt]))
        pproc = pproc.at[tt].set(jnp.where(keep, pl, pproc[tt]))
        return (ceft_arr, ptask, pproc), None

    init = (
        jnp.zeros((v + 1, P), comp_pad.dtype),
        jnp.full((v + 1, P), -1, jnp.int32),
        jnp.full((v + 1, P), -1, jnp.int32),
    )
    (ceft_arr, ptask, pproc), _ = jax.lax.scan(body, init, tables)
    return ceft_arr[:v], ptask[:v], pproc[:v]


def device_inputs(g: TaskGraph, comp: np.ndarray, m: Machine, dtype=jnp.float32):
    t = padded_level_tables(g)
    tables = (
        jnp.asarray(t["tasks"]),
        jnp.asarray(t["par"]),
        jnp.asarray(t["pdata"], dtype),
    )
    comp_pad = jnp.concatenate(
        [jnp.asarray(comp, dtype), jnp.zeros((1, comp.shape[1]), dtype)], axis=0
    )
    return tables, comp_pad, jnp.asarray(m.L, dtype), jnp.asarray(m.bw, dtype)


def ceft_jax(
    g: TaskGraph, comp: np.ndarray, m: Machine, *, relax: Callable = xla_relax
) -> CeftResult:
    tables, comp_pad, L, bw = device_inputs(g, comp, m)
    ceft_arr, ptask, pproc = _sweep(tables, comp_pad, L, bw, relax=relax)
    return _finalize(
        g,
        np.asarray(ceft_arr, np.float64),
        np.asarray(ptask),
        np.asarray(pproc),
    )


def ceft_jax_batch(g: TaskGraph, comps: np.ndarray, Ls: np.ndarray, bws: np.ndarray):
    """vmap over machines that share P (batched re-planning / straggler sweeps).

    comps: (B, v, P); Ls: (B, P); bws: (B, P, P).  Returns the (B, v, P) CEFT
    arrays and predecessor tables (device arrays).
    """
    t = padded_level_tables(g)
    tables = (
        jnp.asarray(t["tasks"]),
        jnp.asarray(t["par"]),
        jnp.asarray(t["pdata"], jnp.float32),
    )
    pad = jnp.zeros((comps.shape[0], 1, comps.shape[2]), jnp.float32)
    comp_pad = jnp.concatenate([jnp.asarray(comps, jnp.float32), pad], axis=1)
    fn = jax.vmap(lambda c, L, b: _sweep(tables, c, L, b))
    return fn(comp_pad, jnp.asarray(Ls, jnp.float32), jnp.asarray(bws, jnp.float32))
