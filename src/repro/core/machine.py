"""Heterogeneous machine model (paper §3.1, G_r).

The paper's key §5 observation: CEFT only needs *classes* of processors
(identical computation + communication behaviour), because a critical path never
contends for resources — ``O(P^2 e)`` with P = number of classes.  The list
schedulers (HEFT/CPOP/CEFT-CPOP) additionally need concrete *instances* with
availability, so a Machine carries both views:

  * class view  : P classes, per-class comm startup L, class-pair bandwidth bw
  * instance view: ``counts[c]`` instances per class, ``inst_class`` mapping

Communication cost of ``data`` bytes from task on processor a to task on
processor b (Definition 3):

    0                                   if a and b are the same *instance*
    L[class(a)] + data / bw[class(a), class(b)]   otherwise

For the CEFT class view "same instance" relaxes to "same class" — the DP may
always co-locate a parent and child of the same class on one instance.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Machine:
    L: np.ndarray        # (P,) communication startup time per class
    bw: np.ndarray       # (P, P) bandwidth between classes (>0)
    counts: np.ndarray   # (P,) number of instances per class

    @property
    def P(self) -> int:
        return int(self.L.shape[0])

    @property
    def n_proc(self) -> int:
        return int(self.counts.sum())

    @property
    def inst_class(self) -> np.ndarray:
        return np.repeat(np.arange(self.P, dtype=np.int32), self.counts)

    # --------------------------------------------------------------- comm costs
    def comm_class(self, data: float, cls_from: int, cls_to: int) -> float:
        """Class-view comm cost (same class => co-locate => 0). Used by CEFT."""
        if cls_from == cls_to:
            return 0.0
        return float(self.L[cls_from] + data / self.bw[cls_from, cls_to])

    def comm_class_vec(self, data) -> np.ndarray:
        """(..., P_from, P_to) comm costs for data of shape (...,). Diagonal 0."""
        data = np.asarray(data, dtype=np.float64)
        c = self.L[:, None] + data[..., None, None] / self.bw
        off = ~np.eye(self.P, dtype=bool)
        return c * off

    def comm_inst(self, data: float, inst_from: int, inst_to: int) -> float:
        """Instance-view comm cost (same instance => 0). Used by schedulers."""
        if inst_from == inst_to:
            return 0.0
        ic = self.inst_class
        a, b = int(ic[inst_from]), int(ic[inst_to])
        return float(self.L[a] + data / self.bw[a, b])

    # ------------------------------------------------------------- mean values
    def mean_comm(self, data) -> np.ndarray:
        """Average comm cost over *distinct ordered instance pairs* (CPOP/HEFT
        use mean communication costs, Topcuoglu et al. 2002)."""
        data = np.asarray(data, dtype=np.float64)
        ic = self.inst_class
        n = self.n_proc
        if n <= 1:
            return np.zeros_like(data)
        La = self.L[ic]                      # (n,)
        inv = 1.0 / self.bw[np.ix_(ic, ic)]  # (n, n)
        off = ~np.eye(n, dtype=bool)
        mean_L = La[:, None].repeat(n, 1)[off].mean()
        mean_inv = inv[off].mean()
        return mean_L + data * mean_inv

    def mean_comp(self, comp_class: np.ndarray) -> np.ndarray:
        """Instance-count-weighted mean execution time, (v,P)->(v,)."""
        w = self.counts / self.counts.sum()
        return comp_class @ w


def uniform_machine(P: int, counts=None, bw: float = 1.0, L: float = 0.0) -> Machine:
    """Homogeneous-communication machine (the RGG-classic setting: a single
    per-edge comm cost, zero startup)."""
    counts = np.ones(P, np.int64) if counts is None else np.asarray(counts, np.int64)
    return Machine(
        L=np.full(P, L, np.float64),
        bw=np.full((P, P), bw, np.float64),
        counts=counts,
    )


def random_machine(
    P: int,
    rng: np.random.Generator,
    *,
    counts=None,
    bw_range: tuple[float, float] = (0.5, 2.0),
    L_range: tuple[float, float] = (0.0, 0.0),
) -> Machine:
    """Heterogeneous communication backbone: symmetric log-uniform bandwidths."""
    lo, hi = np.log(bw_range[0]), np.log(bw_range[1])
    b = np.exp(rng.uniform(lo, hi, size=(P, P)))
    b = np.sqrt(b * b.T)  # symmetric
    L = rng.uniform(L_range[0], L_range[1], size=P)
    counts = np.ones(P, np.int64) if counts is None else np.asarray(counts, np.int64)
    return Machine(L=L.astype(np.float64), bw=b.astype(np.float64), counts=counts)
