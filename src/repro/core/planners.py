"""Planner registry: every scheduler behind one name-keyed signature (ISSUE 10).

The serving stack used to hard-wire ``ceft_cpop``; the baselines in
``heft.py``/``cpop.py``/``bruteforce.py`` never touched the router, the plan
cache, or the bench trajectory.  This module makes the planner a first-class
*value*: a :class:`Plan` result type that carries both the realized schedule
(instance/start/finish, like :class:`~.schedule.Schedule`) and the planner's
critical-path view (cpl, path tasks + classes, a per-class finish surface,
like :class:`~.ceft.CeftResult`), plus a registry mapping planner names to
builders with the single signature

    plan(name, g, comp, m, ceft_result=None) -> Plan

Consumers downstream (``sched/plancache.py``, ``sched/straggler.py``,
``serve/router.py``, ``sched/partitioner.py``) select planners by name only —
``scripts/ci.sh`` greps that ``serve/`` and ``sched/`` never import the
scheduler functions directly.

Duck-typing contract (what lets a Plan drop in anywhere):

* ``proc``/``start``/``finish``/``makespan`` — a valid :class:`Schedule`
  (``validate_schedule`` accepts every registered planner's Plan; property-
  tested over the graph zoo in ``tests/test_planners.py``).
* ``ceft``/``path``/``assignment``/``cpl`` — the :class:`CeftResult` surface
  ``Router._choose`` and ``sched/deadlines.py`` consume.  For list-scheduling
  planners ``ceft[t, c] = start[t] + comp[t, c]`` (the planned per-class
  finish given the realized start) and the path is the planner's own
  critical-path notion: CEFT's mapped path for ``ceft_cpop``, the mean-cost
  CPOP walk for ``cpop``, the averaging-based longest path for the HEFT
  family, and the exact chain-optimal path for the brute-force oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .bruteforce import all_paths, chain_optimal_cost
from .ceft import CeftResult, averaged_critical_path, ceft
from .cpop import _cpop_cp_set, ceft_cpop, cpop
from .heft import ceft_heft_down, ceft_heft_up, heft, heft_down
from .machine import Machine
from .ranks import rank_d, rank_u
from .schedule import Schedule, list_schedule
from .taskgraph import TaskGraph

# Brute force enumerates every source->sink path; refuse unbounded blowup.
_BRUTEFORCE_PATH_CAP = 20_000


@dataclasses.dataclass
class Plan:
    """A realized schedule plus the planner's critical-path view."""

    planner: str
    proc: np.ndarray        # (v,) instance id per task
    start: np.ndarray       # (v,)
    finish: np.ndarray      # (v,)
    eft: np.ndarray         # (v, P) per-class finish surface (CEFT's DP array
                            # for ceft_cpop; start + comp for list planners)
    cpl: float              # the planner's critical-path length
    cp_tasks: tuple[int, ...]    # critical-path vertices, entry -> exit
    cp_classes: tuple[int, ...]  # their processor classes under the plan

    @property
    def makespan(self) -> float:
        return float(self.finish.max())

    # ---------------------------------------------- CeftResult-shaped surface
    @property
    def ceft(self) -> np.ndarray:
        return self.eft

    @property
    def path(self) -> list[tuple[int, int]]:
        return list(zip(self.cp_tasks, self.cp_classes))

    @property
    def assignment(self) -> dict[int, int]:
        return dict(zip(self.cp_tasks, self.cp_classes))

    @property
    def schedule(self) -> Schedule:
        return Schedule(proc=self.proc, start=self.start, finish=self.finish)


@dataclasses.dataclass(frozen=True)
class PlannerSpec:
    """Registry entry: ``build(g, comp, m, ceft_result) -> Plan``."""

    name: str
    build: Callable[[TaskGraph, np.ndarray, Machine, CeftResult | None], Plan]
    uses_ceft: bool = False   # True: consumes a CeftResult (CSR fast path)
    exhaustive: bool = False  # True: exponential-time oracle, small graphs only


def _from_schedule(name: str, g: TaskGraph, comp: np.ndarray, m: Machine,
                   sched: Schedule, cpl: float, cp: list[int]) -> Plan:
    ic = m.inst_class
    return Plan(
        planner=name,
        proc=sched.proc, start=sched.start, finish=sched.finish,
        eft=sched.start[:, None] + comp,
        cpl=float(cpl),
        cp_tasks=tuple(int(t) for t in cp),
        cp_classes=tuple(int(ic[sched.proc[t]]) for t in cp),
    )


def _build_ceft_cpop(g, comp, m, res):
    if res is None:
        res = ceft(g, comp, m)
    sched = ceft_cpop(g, comp, m, res)
    ts, cs = zip(*res.path)
    return Plan(
        planner="ceft_cpop",
        proc=sched.proc, start=sched.start, finish=sched.finish,
        eft=np.asarray(res.ceft, np.float64),
        cpl=float(res.cpl),
        cp_tasks=tuple(int(t) for t in ts),
        cp_classes=tuple(int(c) for c in cs),
    )


def _build_cpop(g, comp, m, res):
    del res
    sched = cpop(g, comp, m)
    cp = _cpop_cp_set(g, rank_u(g, comp, m) + rank_d(g, comp, m))
    # CPOP's realized CP length: the whole set on the one class minimizing its
    # total computation (intra-path comm zeroed) — the Table-3 quantity.
    cpl = float(comp[cp, :].sum(axis=0).min())
    return _from_schedule("cpop", g, comp, m, sched, cpl, cp)


def _build_list(name: str, fn):
    def build(g, comp, m, res):
        del res
        sched = fn(g, comp, m)
        cost, cp = averaged_critical_path(g, comp, m)
        return _from_schedule(name, g, comp, m, sched, cost, cp)
    return build


def chain_optimal_assignment(
    path: list[int], g: TaskGraph, comp: np.ndarray, m: Machine
) -> tuple[float, list[int]]:
    """``bruteforce.chain_optimal_cost`` with argmin backtracking: the exact
    minimum chain cost *and* one class per path vertex achieving it."""
    P = comp.shape[1]
    off = ~np.eye(P, dtype=bool)
    dp = comp[path[0], :].astype(np.float64).copy()
    args: list[np.ndarray] = []
    for a, b in zip(path[:-1], path[1:]):
        ps = g.parents(b)
        data = float(g.parent_data(b)[np.nonzero(ps == a)[0][0]])
        comm = (m.L[:, None] + data / m.bw) * off
        cand = dp[:, None] + comm            # (class_from, class_to)
        args.append(cand.argmin(axis=0))
        dp = comp[b, :] + cand.min(axis=0)
    classes = [int(dp.argmin())]
    for arg in reversed(args):
        classes.append(int(arg[classes[-1]]))
    return float(dp.min()), classes[::-1]


def _build_bruteforce(g, comp, m, res):
    del res
    paths = all_paths(g)
    if len(paths) > _BRUTEFORCE_PATH_CAP:
        raise ValueError(
            f"bruteforce planner: {len(paths)} source->sink paths exceeds the "
            f"cap of {_BRUTEFORCE_PATH_CAP} (exponential oracle; small graphs "
            "only)")
    best_cost, best_path, best_classes = -np.inf, [], []
    for p in paths:
        cost, classes = chain_optimal_assignment(p, g, comp, m)
        if cost > best_cost:
            best_cost, best_path, best_classes = cost, p, classes
    ic = m.inst_class
    first_inst = {c: int(np.nonzero(ic == c)[0][0]) for c in range(m.P)}
    pin = {t: first_inst[c] for t, c in zip(best_path, best_classes)}
    pri = rank_u(g, comp, m) + rank_d(g, comp, m)
    sched = list_schedule(g, comp, m, priority=pri, pin=pin)
    return Plan(
        planner="bruteforce",
        proc=sched.proc, start=sched.start, finish=sched.finish,
        eft=sched.start[:, None] + comp,
        cpl=float(best_cost),
        cp_tasks=tuple(int(t) for t in best_path),
        cp_classes=tuple(int(c) for c in best_classes),
    )


PLANNERS: dict[str, PlannerSpec] = {
    "ceft_cpop": PlannerSpec("ceft_cpop", _build_ceft_cpop, uses_ceft=True),
    "cpop": PlannerSpec("cpop", _build_cpop),
    "heft": PlannerSpec("heft", _build_list("heft", heft)),
    "heft_down": PlannerSpec("heft_down", _build_list("heft_down", heft_down)),
    "ceft_heft_up": PlannerSpec(
        "ceft_heft_up", _build_list("ceft_heft_up", ceft_heft_up)),
    "ceft_heft_down": PlannerSpec(
        "ceft_heft_down", _build_list("ceft_heft_down", ceft_heft_down)),
    "bruteforce": PlannerSpec("bruteforce", _build_bruteforce, exhaustive=True),
}


def planner_names(*, include_exhaustive: bool = True) -> list[str]:
    return [n for n, s in PLANNERS.items()
            if include_exhaustive or not s.exhaustive]


def get_planner(name: str) -> PlannerSpec:
    try:
        return PLANNERS[name]
    except KeyError:
        raise KeyError(
            f"unknown planner {name!r}; registered: {sorted(PLANNERS)}"
        ) from None


def plan(name: str, g: TaskGraph, comp: np.ndarray, m: Machine, *,
         ceft_result: CeftResult | None = None) -> Plan:
    """Run the named planner.  ``ceft_result`` lets CEFT-consuming planners
    reuse a sweep already paid for (e.g. the plan cache's CSR fast path)."""
    return get_planner(name).build(g, comp, m, ceft_result)


def realize(name: str, g: TaskGraph, comp: np.ndarray, m: Machine,
            result: CeftResult | Plan) -> Plan:
    """Turn a cached planning result into a full Plan.

    The plan cache stores a :class:`CeftResult` for CEFT-consuming planners
    (the batched CSR sweep's native output) and a :class:`Plan` for host-path
    planners; callers that need the realized schedule go through here so both
    shapes work."""
    if isinstance(result, Plan):
        return result
    return plan(name, g, comp, m, ceft_result=result)


def averaged_path_misidentified(
    g: TaskGraph, comp: np.ndarray, m: Machine, *,
    ceft_result: CeftResult | None = None, tol: float = 1e-9,
) -> bool:
    """Does the averaging-based critical path misidentify the true one?

    The paper's headline comparison (§7.3, 83.99%): the mean-cost longest
    path (``averaged_critical_path`` — CPOP/HEFT's estimate) is *misidentified*
    when, under its own optimal chain assignment, it is strictly shorter than
    CEFT's critical-path length — i.e. some other path is the real constraint.
    Equal-cost alternate paths are NOT misidentified (oracle-aligned: this
    predicate agrees with comparing against ``bruteforce_cpl`` whenever CEFT
    is exact, which ``tests/test_planners.py`` checks on small graphs)."""
    res = ceft_result if ceft_result is not None else ceft(g, comp, m)
    _, avg_tasks = averaged_critical_path(g, comp, m)
    realized = chain_optimal_cost(avg_tasks, g, comp, m)
    return bool(realized < float(res.cpl) - tol * max(1.0, abs(float(res.cpl))))
