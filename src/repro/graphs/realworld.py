"""Real-world application DAGs (paper §7.2): Gaussian Elimination, FFT,
Molecular Dynamics, Epigenomics.  Structure only -- weights come from
``classic_workload`` / ``interval_workload`` (the paper re-weights these known
structures with varying CCR and beta)."""
from __future__ import annotations

import numpy as np

from ..core.taskgraph import TaskGraph, from_edges


def gaussian_elimination(m: int) -> TaskGraph:
    """GE task graph on an m x m matrix (Wu & Gajski; paper §7.2.2).

    (m-1) pivot tasks L_k and, per step k, update tasks U_{k,j} (j=k+1..m).
    Total (m^2 + m - 2)/2 tasks (m=5 -> 14, matching Fig. 3a).
    Edges: L_k -> U_{k,j}; U_{k,k+1} -> L_{k+1}; U_{k,j} -> U_{k+1,j} (j>k+1).
    """
    ids: dict[tuple, int] = {}
    nxt = 0

    def nid(key):
        nonlocal nxt
        if key not in ids:
            ids[key] = nxt
            nxt += 1
        return ids[key]

    edges = []
    for k in range(1, m):
        lk = nid(("L", k))
        for j in range(k + 1, m + 1):
            u = nid(("U", k, j))
            edges.append((lk, u, 1.0))
            if j == k + 1 and k + 1 < m:
                edges.append((u, nid(("L", k + 1)), 1.0))
            elif j > k + 1 and k + 1 < m:
                edges.append((u, nid(("U", k + 1, j)), 1.0))
    assert nxt == (m * m + m - 2) // 2
    return from_edges(nxt, edges, sort_topologically=True)


def fft_graph(m: int) -> TaskGraph:
    """FFT task graph on an m-point input (m a power of two; Fig. 3b).

    2m-1 recursive-call tasks (a binary tree) above the line, m*log2(m)
    butterfly tasks below; butterfly stage s pairs elements differing in one
    bit.  All source->sink paths have equal structure (every path is critical).
    """
    assert m >= 2 and (m & (m - 1)) == 0, "m must be a power of two"
    lg = int(np.log2(m))
    edges = []
    # recursion tree: node (d, i), d=0..lg, 2^d nodes per depth
    def rid(d, i):
        return (1 << d) - 1 + i

    for d in range(lg):
        for i in range(1 << d):
            edges.append((rid(d, i), rid(d + 1, 2 * i), 1.0))
            edges.append((rid(d, i), rid(d + 1, 2 * i + 1), 1.0))
    n_rec = 2 * m - 1
    # butterfly stages: stage s (1..lg), m tasks each
    def bid(s, i):
        return n_rec + (s - 1) * m + i

    for i in range(m):  # leaves feed stage 1
        for j in (i, i ^ (m >> 1)):
            edges.append((rid(lg, i), bid(1, j), 1.0))
    for s in range(1, lg):
        half = m >> (s + 1)
        for i in range(m):
            for j in (i, i ^ half):
                edges.append((bid(s, i), bid(s + 1, j), 1.0))
    n = n_rec + lg * m
    return from_edges(n, edges, sort_topologically=True)


def molecular_dynamics() -> TaskGraph:
    """The Kim & Browne modified molecular-dynamics DAG (paper Fig. 4,
    redrawn).  A fixed 41-task irregular graph; edges transcribed from the
    commonly reproduced figure (irregular fan-outs, depth 8)."""
    E = [
        (0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (0, 6),
        (1, 7), (1, 8), (2, 8), (2, 9), (3, 9), (3, 10), (4, 10), (4, 11),
        (5, 11), (5, 12), (6, 12), (6, 13),
        (7, 14), (8, 14), (8, 15), (9, 15), (9, 16), (10, 16), (10, 17),
        (11, 17), (11, 18), (12, 18), (12, 19), (13, 19),
        (14, 20), (15, 20), (15, 21), (16, 21), (16, 22), (17, 22),
        (17, 23), (18, 23), (18, 24), (19, 24),
        (20, 25), (21, 25), (21, 26), (22, 26), (22, 27), (23, 27),
        (23, 28), (24, 28),
        (25, 29), (25, 30), (26, 30), (26, 31), (27, 31), (27, 32), (28, 32),
        (29, 33), (30, 33), (30, 34), (31, 34), (31, 35), (32, 35),
        (33, 36), (34, 36), (34, 37), (35, 37),
        (36, 38), (37, 38), (37, 39), (36, 39),
        (38, 40), (39, 40),
    ]
    return from_edges(41, [(a, b, 1.0) for a, b in E])


def epigenomics(B: int) -> TaskGraph:
    """Epigenomics workflow (USC Pegasus; paper §7.2.4): fastQSplit fans out to
    B parallel 4-stage chains (filterContams -> sol2sanger -> fast2bfq -> map),
    merged by mapMerge -> maqIndex -> pileup.  4B + 4 tasks; wide and shallow.
    """
    edges = []
    split = 0
    nxt = 1
    chain_ends = []
    for _ in range(B):
        prev = split
        for _stage in range(4):
            edges.append((prev, nxt, 1.0))
            prev = nxt
            nxt += 1
        chain_ends.append(prev)
    merge, index, pileup = nxt, nxt + 1, nxt + 2
    for e in chain_ends:
        edges.append((e, merge, 1.0))
    edges.append((merge, index, 1.0))
    edges.append((index, pileup, 1.0))
    return from_edges(pileup + 1, edges)


REALWORLD = {
    "GE": lambda size=8: gaussian_elimination(size),
    "FFT": lambda size=16: fft_graph(size),
    "MD": lambda size=None: molecular_dynamics(),
    "EW": lambda size=8: epigenomics(size),
}
