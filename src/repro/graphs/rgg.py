"""Randomly generated workloads (paper §7.1).

Four families sharing one structure generator (parameters n, o, c, alpha, beta,
gamma) but differing in how execution times are drawn:

  * RGG-classic — eq. (5): w_ij ~ U(w_i (1-beta/2), w_i (1+beta/2)) -- at most a
    3x fast/slow ratio, Topcuoglu-style; homogeneous communication backbone.
  * RGG-low / medium / high — eq. (6) two-node-weight cost model:
    Cost(t_i, p_j) = w1(t_i)/W1(p_j) + w0(t_i)/W0(p_j), node weights drawn from
    two intervals {I1, I2} swapped with probability beta -- tasks can be fast on
    some processors while those processors are not universally faster.

beta is given in percent ({10,25,50,75,95}) as in §7.1 and divided by 100.
Each processor in the paper's processor graphs has its own weights, so classes
== processors (counts of 1) for these workloads.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.machine import Machine, random_machine, uniform_machine
from ..core.taskgraph import TaskGraph, from_edges

INTERVALS = {
    "resource": ((1e2, 1e3), (1e3, 1e4)),
    "low": ((1e2, 1e3), (1e3, 1e4)),
    "medium": ((1e2, 1e3), (1e4, 1e5)),
    "high": ((1e2, 1e3), (1e5, 1e6)),
}


@dataclasses.dataclass
class Workload:
    graph: TaskGraph
    comp: np.ndarray  # (v, P) class-view execution times
    machine: Machine
    meta: dict


# --------------------------------------------------------------------- structure
def rgg_structure(
    n: int, o: float, alpha: float, rng: np.random.Generator
) -> tuple[list[tuple[int, int]], np.ndarray]:
    """Level-structured DAG: height ~ sqrt(n)/alpha, level widths ~ U(mean =
    alpha*sqrt(n)); every vertex has >=1 parent in an earlier level (except
    level 0) and average out-degree ~ o.  Returns (edges, level_of_vertex)."""
    height = max(2, min(n, int(round(np.sqrt(n) / alpha))))
    mean_w = max(1.0, alpha * np.sqrt(n))
    widths = []
    left = n
    for lvl in range(height):
        remaining_lvls = height - lvl
        if remaining_lvls == 1:
            w = left
        else:
            w = int(np.clip(rng.uniform(0.5 * mean_w, 1.5 * mean_w), 1, left - (remaining_lvls - 1)))
        widths.append(w)
        left -= w
        if left == 0:
            break
    levels: list[np.ndarray] = []
    start = 0
    for w in widths:
        levels.append(np.arange(start, start + w))
        start += w
    lvl_of = np.zeros(n, np.int32)
    for li, l in enumerate(levels):
        lvl_of[l] = li

    edges: set[tuple[int, int]] = set()
    # every non-root vertex gets a parent in the previous level (connectivity)
    for li in range(1, len(levels)):
        for v in levels[li]:
            u = int(rng.choice(levels[li - 1]))
            edges.add((u, int(v)))
    # extra forward edges to hit average out-degree o
    target = int(o * n)
    later = [np.concatenate(levels[li + 1 :]) if li + 1 < len(levels) else np.empty(0, int)
             for li in range(len(levels))]
    attempts = 0
    while len(edges) < target and attempts < 20 * target:
        attempts += 1
        u = int(rng.integers(0, n))
        cand = later[lvl_of[u]]
        if cand.size == 0:
            continue
        v = int(rng.choice(cand))
        edges.add((u, v))
    return sorted(edges), lvl_of


def _skew_mask(n: int, lvl_of: np.ndarray, gamma: float, rng: np.random.Generator) -> np.ndarray:
    """gamma-skewness (§7.1): larger gamma concentrates computation in 'hot'
    pockets.  We mark ~gamma of the levels hot; hot tasks get x(1 + 9*gamma)
    weight (an interpretation -- the paper gives no formula)."""
    n_lvl = int(lvl_of.max()) + 1
    hot_levels = rng.random(n_lvl) < gamma
    factor = np.where(hot_levels[lvl_of], 1.0 + 9.0 * gamma, 1.0)
    return factor


# ----------------------------------------------------------------------- weights
def classic_workload(
    g: TaskGraph,
    P: int,
    c: float,
    beta: float,
    rng: np.random.Generator,
    *,
    gamma: float = 0.0,
    lvl_of: np.ndarray | None = None,
    w_dag_range: tuple[float, float] = (1.0, 100.0),
) -> Workload:
    """eq. (5)/(7) weighting on an existing structure + homogeneous comm."""
    b = beta / 100.0 if beta > 1 else beta
    w_dag = rng.uniform(*w_dag_range)
    w = rng.uniform(0, 2 * w_dag, size=g.n)
    if gamma > 0 and lvl_of is not None:
        w = w * _skew_mask(g.n, lvl_of, gamma, rng)
    comp = w[:, None] * rng.uniform(1 - b / 2, 1 + b / 2, size=(g.n, P))
    # edge weight = w_src * c * U(1 +- beta/2); machine is homogeneous (bw=1, L=0)
    src = np.repeat(np.arange(g.n), np.diff(g.cindptr))
    cdata = w[src] * c * rng.uniform(1 - b / 2, 1 + b / 2, size=g.n_edges)
    g2 = _with_edge_data(g, cdata)
    m = uniform_machine(P)
    return Workload(g2, comp, m, {"kind": "classic", "c": c, "beta": beta})


def interval_workload(
    g: TaskGraph,
    P: int,
    c: float,
    beta: float,
    kind: str,
    rng: np.random.Generator,
    *,
    gamma: float = 0.0,
    lvl_of: np.ndarray | None = None,
    hetero_bw: bool = True,
    proc_beta: float = 0.5,
) -> Workload:
    """eq. (6) two-node-weight cost model (RGG-low/medium/high).

    The paper uses *one fixed set* of six processor graphs across every
    workload, so the processor population is a (roughly even) mix of the two
    interval orderings regardless of the workload's beta -- hence the separate
    ``proc_beta`` defaulting to 0.5.
    """
    b = beta / 100.0 if beta > 1 else beta
    tI1, tI2 = INTERVALS[kind]
    rI1, rI2 = INTERVALS["resource"]

    def draw_two(nu: int, I1, I2, prob):
        swap = rng.random(nu) >= prob
        a = rng.uniform(*I1, size=nu)
        z = rng.uniform(*I2, size=nu)
        w1 = np.where(swap, z, a)
        w0 = np.where(swap, a, z)
        return w1, w0

    tw1, tw0 = draw_two(g.n, tI1, tI2, b)
    if gamma > 0 and lvl_of is not None:
        f = _skew_mask(g.n, lvl_of, gamma, rng)
        tw1, tw0 = tw1 * f, tw0 * f
    pW1, pW0 = draw_two(P, rI1, rI2, proc_beta)
    comp = tw1[:, None] / pW1[None, :] + tw0[:, None] / pW0[None, :]  # eq. (6)

    # edge weight from the task's mean execution time (scalar proxy for w_i)
    wbar = comp.mean(axis=1)
    src = np.repeat(np.arange(g.n), np.diff(g.cindptr))
    cdata = wbar[src] * c * rng.uniform(1 - b / 2, 1 + b / 2, size=g.n_edges)
    g2 = _with_edge_data(g, cdata)
    m = (
        random_machine(P, rng, bw_range=(0.5, 2.0))
        if hetero_bw
        else uniform_machine(P)
    )
    return Workload(g2, comp, m, {"kind": kind, "c": c, "beta": beta})


def _with_edge_data(g: TaskGraph, cdata: np.ndarray) -> TaskGraph:
    """Rebuild the graph with new edge data (cdata aligned to children CSR)."""
    src = np.repeat(np.arange(g.n), np.diff(g.cindptr))
    edges = list(zip(src.tolist(), g.cindices.tolist(), cdata.tolist()))
    return from_edges(g.n, edges)


# ------------------------------------------------------------------ entry point
def rgg(
    kind: str,
    n: int,
    P: int,
    rng: np.random.Generator,
    *,
    o: float = 4.0,
    c: float = 1.0,
    alpha: float = 1.0,
    beta: float = 50.0,
    gamma: float = 0.1,
) -> Workload:
    """One experiment's workload: structure + weights + machine.

    kind in {"classic", "low", "medium", "high"}.
    """
    edges, lvl_of = rgg_structure(n, o, alpha, rng)
    g = from_edges(n, [(a, b, 1.0) for a, b in edges])
    if kind == "classic":
        wl = classic_workload(g, P, c, beta, rng, gamma=gamma, lvl_of=lvl_of)
    else:
        wl = interval_workload(g, P, c, beta, kind, rng, gamma=gamma, lvl_of=lvl_of)
    wl.meta.update({"n": n, "P": P, "o": o, "alpha": alpha, "gamma": gamma})
    return wl
