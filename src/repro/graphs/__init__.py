"""repro.graphs — workload substrate: the paper's random graph generator and
real-world application DAGs."""
from .irregular import heavy_tail_fan_in, star_fan_in
from .realworld import epigenomics, fft_graph, gaussian_elimination, molecular_dynamics
from .rgg import Workload, classic_workload, interval_workload, rgg_structure, rgg

__all__ = [
    "Workload", "classic_workload", "epigenomics", "fft_graph",
    "gaussian_elimination", "heavy_tail_fan_in", "interval_workload",
    "molecular_dynamics", "rgg", "rgg_structure", "star_fan_in",
]
