"""Irregular fan-in/fan-out DAG structures (ISSUE 3 benchmark shapes).

These are the shapes where the padded dense level tables degrade worst: the
(n_levels, Wmax, Dmax) padding is driven by the single widest level and the
single largest in-degree, so a star fan-in pads every task to in-degree n-1
and a heavy-tailed in-degree distribution pads the mean task to the tail.
The CSR sweep does O(e·P²) work regardless.
"""
from __future__ import annotations

import numpy as np

from ..core.taskgraph import TaskGraph, from_edge_arrays


def star_fan_in(n: int, data: float = 1.0) -> TaskGraph:
    """n-1 independent sources all feeding one sink: e = n-1, Dmax = n-1."""
    src = np.arange(n - 1, dtype=np.int32)
    dst = np.full(n - 1, n - 1, np.int32)
    return from_edge_arrays(n, src, dst, np.full(n - 1, data))


def heavy_tail_fan_in(
    n: int, rng: np.random.Generator, *, tail: float = 1.0, data: float = 1.0
) -> TaskGraph:
    """Pareto(tail)-distributed in-degrees: most tasks have a few parents, a
    few tasks have hundreds (in-degree max >> mean, the re-planning-loop DAG
    shape from sched/straggler).  Connected by construction (every non-root
    vertex draws >= 1 parent among earlier ids)."""
    srcs: list[np.ndarray] = []
    dsts: list[np.ndarray] = []
    k = np.minimum(np.arange(n), 1 + rng.pareto(tail, size=n).astype(np.int64))
    for j in range(1, n):
        ps = rng.choice(j, size=int(k[j]), replace=False)
        srcs.append(ps)
        dsts.append(np.full(ps.shape[0], j, np.int64))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    return from_edge_arrays(n, src, dst, np.full(src.shape[0], data))
