"""Fault-tolerant checkpointing: per-leaf shards + manifest, atomic rename,
checksum verification, async writer, automatic fallback to the newest intact
checkpoint.

Layout:  <dir>/step_<n>/  {manifest.json, 000000.npy, 000001.npy, ...}
A checkpoint is valid iff the manifest exists, lists every shard, and every
shard's CRC matches.  Writes go to ``<dir>/.tmp_step_<n>`` and are renamed
into place only after fsync -- a crash mid-write can never corrupt the newest
valid checkpoint (restore() simply skips incomplete/corrupt directories).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str | os.PathLike, step: int, tree, *, async_: bool = False):
    """Device->host copy happens synchronously (consistent snapshot); disk IO
    optionally on a background thread.  Returns the Thread when async_."""
    host_leaves = [np.asarray(x) for x in jax.tree.leaves(tree)]

    def write():
        d = Path(ckpt_dir)
        tmp = d / f".tmp_step_{step}"
        final = d / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for i, a in enumerate(host_leaves):
            fn = f"{i:06d}.npy"
            np.save(tmp / fn, a)
            crc = zlib.crc32((tmp / fn).read_bytes())
            manifest["leaves"].append(
                {"file": fn, "shape": list(a.shape), "dtype": str(a.dtype), "crc": crc}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        fd = os.open(tmp, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _verify(d: Path) -> bool:
    mf = d / "manifest.json"
    if not mf.exists():
        return False
    try:
        manifest = json.loads(mf.read_text())
        for leaf in manifest["leaves"]:
            f = d / leaf["file"]
            if not f.exists() or zlib.crc32(f.read_bytes()) != leaf["crc"]:
                return False
        return True
    except Exception:
        return False


def available_steps(ckpt_dir: str | os.PathLike) -> list[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return []
    steps = []
    for sub in d.iterdir():
        if sub.name.startswith("step_") and sub.is_dir():
            try:
                steps.append(int(sub.name.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def latest_valid(ckpt_dir: str | os.PathLike) -> int | None:
    """Newest checkpoint that passes full verification (corrupt/incomplete
    checkpoints are skipped -- the node-failure recovery path)."""
    for step in reversed(available_steps(ckpt_dir)):
        if _verify(Path(ckpt_dir) / f"step_{step}"):
            return step
    return None


def restore(ckpt_dir: str | os.PathLike, step: int, target_tree, shardings=None):
    """Restore into the structure of target_tree; optionally device_put with
    per-leaf shardings (elastic restore onto a different mesh)."""
    d = Path(ckpt_dir) / f"step_{step}"
    if not _verify(d):
        raise IOError(f"checkpoint {d} is missing or corrupt")
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = jax.tree.flatten(target_tree)
    assert len(leaves) == len(manifest["leaves"]), "tree structure mismatch"
    out = []
    sh_leaves = jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
    for ref, leaf, sh in zip(manifest["leaves"], leaves, sh_leaves):
        a = np.load(d / ref["file"])
        assert list(a.shape) == list(ref["shape"])
        if hasattr(leaf, "dtype"):
            a = a.astype(leaf.dtype)
        out.append(jax.device_put(a, sh) if sh is not None else a)
    return jax.tree.unflatten(treedef, out)
