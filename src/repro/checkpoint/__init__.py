from .checkpointer import available_steps, latest_valid, restore, save
__all__ = ["available_steps", "latest_valid", "restore", "save"]
