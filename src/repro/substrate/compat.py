"""Feature-detected mesh/sharding implementations for both JAX generations.

Generation map (all resolved per call, never cached, so monkeypatching the
jax module flips the substrate):

    operation             modern (>= 0.6)                     legacy (0.4.x)
    -------------------   ---------------------------------   ------------------------------
    make_mesh             jax.make_mesh(axis_types=Auto...)   jax.make_mesh / Mesh(reshape)
    mesh_context          jax.set_mesh / sharding.use_mesh    Mesh.__enter__
    current_abstract_mesh sharding.get_abstract_mesh          pxla thread_resources physical
    constrain             with_sharding_constraint            with_sharding_constraint
                          (no-op when no mesh is active, both generations)
"""
from __future__ import annotations

import contextlib
import math
import os
import socket
from typing import Any, Iterator, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec


def jax_mesh_api() -> str:
    """'modern' when the >=0.6 mesh-context API is present, else 'legacy'."""
    if getattr(jax, "set_mesh", None) is not None or \
            getattr(jax.sharding, "use_mesh", None) is not None:
        return "modern"
    return "legacy"


# ------------------------------------------------------------------ make_mesh
def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices: Sequence[Any] | None = None) -> Mesh:
    """Build a Mesh of `shape` over `axes`, optionally from explicit devices.

    On modern JAX the axes are marked AxisType.Auto (the compiler keeps full
    sharding freedom, matching 0.4.x semantics).  Raises RuntimeError when
    fewer devices exist than the shape needs.
    """
    shape = tuple(int(s) for s in shape)
    axes = tuple(axes)
    n = math.prod(shape)
    devs = np.asarray(devices if devices is not None else jax.devices()).ravel()
    if devs.size < n:
        raise RuntimeError(f"need {n} devices, have {devs.size}")
    axis_type = getattr(jax.sharding, "AxisType", None)
    mk = getattr(jax, "make_mesh", None)
    if axis_type is not None and mk is not None:
        return mk(shape, axes, devices=list(devs[:n]),
                  axis_types=(axis_type.Auto,) * len(axes))
    return Mesh(devs[:n].reshape(shape), axes)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# --------------------------------------------------------------- mesh context
@contextlib.contextmanager
def mesh_context(mesh: Mesh) -> Iterator[Mesh]:
    """Activate `mesh` for jit tracing / sharding constraints in this block."""
    setter = getattr(jax, "set_mesh", None) or \
        getattr(jax.sharding, "use_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def current_abstract_mesh():
    """The mesh active for the current trace, or None when there is none.

    Modern JAX reports the abstract mesh; legacy JAX the physical mesh from
    the thread-local resource env.  Both expose .shape / .axis_names, which
    is all callers may rely on.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        am = getter()
        if am is None or am.empty:
            return None
        return am
    from jax.interpreters import pxla

    pm = pxla.thread_resources.env.physical_mesh
    return None if pm.empty else pm


def current_axis_sizes() -> dict[str, int] | None:
    """axis-name -> size of the active mesh, or None outside any mesh."""
    am = current_abstract_mesh()
    return None if am is None else dict(am.shape)


# ------------------------------------------------------------------ topology
def host_id() -> str:
    """A stable identifier for this host (the pool's placement unit)."""
    return socket.gethostname()


def process_topology() -> dict:
    """Host/process placement of the CURRENT process — the seam the engine
    pool probes through: same pid => in-process transfer, same host / other
    pid => pipe transport, other host => network (future).

    Accelerator facts are best-effort: they initialize the jax backend, and a
    worker that cannot (or a caller probing before backend setup) still gets
    the host/process identity.
    """
    info: dict = {"host": host_id(), "pid": os.getpid(),
                  "n_cpus": os.cpu_count() or 1}
    try:
        info["platform"] = jax.default_backend()
        info["n_devices"] = jax.device_count()
    except Exception:  # pragma: no cover - backend init failure
        info["platform"] = None
        info["n_devices"] = 0
    return info


# ------------------------------------------------------------- cost analysis
def compiled_cost_analysis(compiled) -> dict:
    """Normalize Compiled.cost_analysis() across generations.

    0.4.x returns a one-element list of dicts (one per program); modern JAX
    returns the dict directly.  Always returns a dict ({} when XLA offers no
    analysis).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


# ----------------------------------------------------------------- shard_map
def shard_map(f, *, mesh: Mesh, in_specs, out_specs):
    """shard_map across generations, replication checking off.

    Modern JAX: jax.shard_map (check_vma, earlier check_rep).  Legacy:
    jax.experimental.shard_map.shard_map (check_rep).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm

    import inspect

    params = inspect.signature(sm).parameters
    check_kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{check_kw: False})


# ----------------------------------------------------------------- constrain
def constrain_spec(x, spec: PartitionSpec):
    """with_sharding_constraint that no-ops when no mesh is active."""
    if current_abstract_mesh() is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def degrade_spec(shape: Sequence[int],
                 candidates: Sequence[Sequence[str]],
                 sizes: dict[str, int]) -> PartitionSpec:
    """Greedy divisibility degradation: per dimension, keep the candidate
    mesh axes (outermost first) that exist in `sizes`, are not yet used, and
    whose cumulative product divides the dimension.  The single source of
    this algorithm -- models.common.resolve_spec layers logical-name lookup
    on top of it.
    """
    out: list[Any] = []
    used: set[str] = set()
    for dim, names in zip(shape, candidates):
        keep: list[str] = []
        shard = 1
        for ax in names:
            if ax is None:
                continue
            if ax in sizes and ax not in used and dim % (shard * sizes[ax]) == 0:
                keep.append(ax)
                shard *= sizes[ax]
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    return PartitionSpec(*out)


def constrain(x, *axes):
    """Constrain `x` by mesh-axis names, degrading gracefully.

    Each entry is a mesh axis name, a tuple of names, or None.  Axes absent
    from the active mesh or not dividing the dimension are dropped; with no
    active mesh the call is the identity.
    """
    sizes = current_axis_sizes()
    if not sizes:
        return x
    cands = [entry if isinstance(entry, tuple) else (entry,) for entry in axes]
    spec = degrade_spec(x.shape, cands, sizes)
    return jax.lax.with_sharding_constraint(x, spec)
