"""Version-portable device-mesh and sharding substrate.

Single choke point for every JAX API that changed across the 0.4.x -> 0.6+
mesh redesign (``jax.set_mesh``, ``jax.sharding.AxisType``,
``jax.sharding.get_abstract_mesh``, ``jax.sharding.use_mesh``).  No module
outside this package may touch those names directly -- scripts/ci.sh greps
for violations.

All feature detection happens at call time (``getattr`` on the live jax
modules), so tests can monkeypatch either API generation onto the installed
jax and the substrate follows.
"""
from .compat import (
    compiled_cost_analysis,
    constrain,
    constrain_spec,
    current_abstract_mesh,
    current_axis_sizes,
    degrade_spec,
    host_id,
    jax_mesh_api,
    make_mesh,
    mesh_axis_sizes,
    mesh_context,
    process_topology,
    shard_map,
)

__all__ = [
    "compiled_cost_analysis",
    "constrain",
    "constrain_spec",
    "current_abstract_mesh",
    "current_axis_sizes",
    "degrade_spec",
    "host_id",
    "jax_mesh_api",
    "make_mesh",
    "mesh_axis_sizes",
    "mesh_context",
    "process_topology",
    "shard_map",
]
