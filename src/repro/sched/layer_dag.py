"""Lower an architecture config to a pipeline task DAG with per-device-class
costs -- the bridge between the LM stack and the paper's scheduler.

The DAG is the (microbatch x stage) grid of pipeline execution:

    fwd(mb, s-1) -> fwd(mb, s)            activations flow between stages
    fwd(mb, s)   -> bwd(mb, s)            stashed activations (training)
    bwd(mb, s+1) -> bwd(mb, s)            gradient flow (training)

Stages: embed, layer_0..layer_{L-1}, head.  Node cost on a device class is
the roofline max(flops/peak, bytes/bw) of that stage for one microbatch.

Device classes are *slices*, sized so their compute/bandwidth balances cross
(v5e-96 is flops-richer, v5p-32 bandwidth-richer): attention-heavy stages are
compute-bound and favor the former, SSM/MoE/decode stages are bandwidth-bound
and favor the latter -- the CPU/GPU matching structure of the paper (§2),
realized on a TPU fleet.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..configs.base import ArchConfig, ShapeCell
from ..core.machine import Machine
from ..core.taskgraph import TaskGraph, from_edges


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    name: str
    flops: float        # peak FLOP/s of the slice (bf16)
    hbm_bw: float       # bytes/s aggregate of the slice
    link_bw: float      # bytes/s egress of the slice
    count: int          # available slices


DEFAULT_FLEET = [
    # 96 x v5e chips: 18.9 PF/s, 78.6 TB/s  (flops-rich)
    DeviceClass("v5e-96", 96 * 197e12, 96 * 819e9, 50e9, 12),
    # 32 x v5p chips: 14.7 PF/s, 88.5 TB/s  (bandwidth-rich)
    DeviceClass("v5p-32", 32 * 459e12, 32 * 2765e9, 90e9, 6),
    # thermally degraded v5e slice (the straggler scenario)
    DeviceClass("v5e-96-degraded", 48 * 197e12, 48 * 819e9, 25e9, 4),
    # host CPUs (frontends, embeds, aux work)
    DeviceClass("host-cpu", 3e12, 100e9, 12.5e9, 32),
]


def fleet_machine(fleet=None) -> Machine:
    fleet = fleet or DEFAULT_FLEET
    P = len(fleet)
    L = np.full(P, 1e-5)                      # ~10us collective setup
    bw = np.empty((P, P))
    for i, a in enumerate(fleet):
        for j, b in enumerate(fleet):
            bw[i, j] = min(a.link_bw, b.link_bw)
    counts = np.array([c.count for c in fleet], np.int64)
    return Machine(L=L, bw=bw, counts=counts)


def _stage_costs(cfg: ArchConfig, kind: str, tokens: int) -> tuple[list[str], list[float], list[float]]:
    """Per-stage (label, flops, hbm bytes) for `tokens` tokens (one microbatch)."""
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.hd
    mult = 3 if cfg.mlp_style == "swiglu" else 2
    labels = ["embed"]
    flops = [2.0 * tokens * d]
    bytes_ = [2.0 * min(cfg.vocab, tokens) * d + 4.0 * tokens * d]
    pattern = cfg.layer_pattern()
    for layer in range(cfg.n_layers):
        mixer, channel = pattern[layer % cfg.period]
        f = 0.0
        b = 0.0
        if mixer == "attn":
            f += 2 * tokens * d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
            f += 2 * tokens * cfg.n_heads * hd * d
            ctx = tokens if kind != "decode" else cfg.window or tokens
            f += 4 * tokens * ctx * cfg.n_heads * hd
            b += 2 * d * hd * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)
            if kind == "decode":
                b += 2 * 2 * ctx * cfg.n_kv_heads * hd  # KV cache stream
        else:
            di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            f += 2 * tokens * d * (2 * di + 2 * N + H) + 2 * tokens * di * d
            f += 6 * tokens * di * N + 2 * tokens * cfg.ssm_chunk * di
            b += 2 * (d * (2 * di + 2 * N + H) + di * d)
            if kind == "decode":
                b += 4 * H * (di // max(H, 1)) * N  # recurrent state read/write
        if channel == "mlp":
            f += 2 * mult * tokens * d * ff
            b += 2 * mult * d * ff
        elif channel == "moe":
            f += 2 * mult * tokens * cfg.top_k * d * ff
            b += 2 * mult * d * ff * min(cfg.n_experts, max(cfg.top_k * tokens, 1))
        b += 4.0 * tokens * d  # residual stream in/out
        labels.append(f"L{layer}:{mixer}/{channel}")
        flops.append(f)
        bytes_.append(b)
    labels.append("head")
    flops.append(2.0 * tokens * d * cfg.vocab)
    bytes_.append(2.0 * d * cfg.vocab + 4.0 * tokens * d)
    return labels, flops, bytes_


def build_layer_dag(cfg: ArchConfig, cell: ShapeCell, fleet=None, n_micro: int = 8):
    """Returns (TaskGraph, comp (v,P), Machine, labels).

    Node v = mb * n_stages + s (fwd), then the mirrored bwd grid for training.
    """
    fleet = fleet or DEFAULT_FLEET
    m = fleet_machine(fleet)
    if cell.kind == "decode":
        n_micro = 1
    total_tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    tokens = max(1, total_tokens // n_micro)
    s_labels, s_flops, s_bytes = _stage_costs(cfg, cell.kind, tokens)
    S = len(s_labels)
    act = 2.0 * tokens * cfg.d_model

    train = cell.kind == "train"
    labels: list[str] = []
    flops: list[float] = []
    bytes_: list[float] = []
    edges: list[tuple[int, int, float]] = []

    def fid(mb, s):
        return mb * S + s

    def bid(mb, s):
        return n_micro * S + mb * S + (S - 1 - s)  # bwd nodes in topo order

    for mb in range(n_micro):
        for s in range(S):
            labels.append(f"mb{mb}/{s_labels[s]}")
            flops.append(s_flops[s])
            bytes_.append(s_bytes[s])
            if s > 0:
                edges.append((fid(mb, s - 1), fid(mb, s), act))
    if train:
        for mb in range(n_micro):
            for s in range(S - 1, -1, -1):
                labels.append(f"mb{mb}/{s_labels[s]}'")
                flops.append(2.0 * s_flops[s])
                bytes_.append(2.0 * s_bytes[s])
        for mb in range(n_micro):
            for s in range(S):
                edges.append((fid(mb, s), bid(mb, s), act))      # stashed acts
                if s + 1 < S:
                    edges.append((bid(mb, s + 1), bid(mb, s), act))  # grad flow

    g = from_edges(len(labels), edges)
    v = len(labels)
    comp = np.empty((v, m.P))
    for j, cl in enumerate(fleet):
        comp[:, j] = np.maximum(np.asarray(flops) / cl.flops,
                                np.asarray(bytes_) / cl.hbm_bw)
    return g, comp, m, labels
