"""Unified plan cache: invalidate-don't-recompute across core/sched/serve.

The paper's critical path is only useful online if it is cheap to keep
current.  Before this module the planning state was smeared across three
layers — ``core/ceft_jax.py`` held a one-slot identity cache for the
graph-derived device tables and a one-slot content cache for request graphs,
``sched/straggler.py`` content-hashed its own nominal baseline, and the
router re-planned everything it drained every tick.  A single EWMA cost
delta or one arrival forced a full O(e·P²) re-sweep of every plan.

This module is now the single owner of that state, in three layers:

* **Graph store** (:func:`graph_for`) — content-keyed LRU mapping edge
  arrays to built :class:`TaskGraph` objects.  Structurally-equal arrays map
  to the SAME object, which is what makes the identity-keyed device-state
  store below hit for callers that rebuild their DAG every tick.
* **Device-state store** (:func:`device_state`) — identity-keyed LRU holding
  each graph's fused super-step tables on device (runs, padded sources, v_b,
  per-run level spans).  TaskGraph is frozen/immutable and entries pin the
  graph object, so identity keying cannot go stale.
* **Plan store** (:class:`PlanCache`) — (slot, planner, graph, machine)-keyed
  plans with their per-run carry snapshots, a reverse index from workload
  class to the plans whose DAG contains it, and dirty-frontier re-sweeps.
  The planner name comes from the ``core/planners.py`` registry: CEFT keeps
  the batched CSR fast path, list-scheduling planners go through a host path
  that still populates the cache and the reverse index.

Invariant: **invalidate-don't-recompute** (README "Incremental planning") —
a cost delta may only SKIP work, never change the resulting schedule, and no
delta handler anywhere in the tree recomputes a plan inline.  Invalidation is
therefore advisory — it marks plans dirty through the reverse index so the
router stops short-circuiting on them — while :meth:`PlanCache.plan` always
byte-compares the stored float32 cost plane against the requested one before
reusing anything.  Equal bytes => the cached result IS the from-scratch
result; changed bytes => re-sweep, resuming at the lowest fused run whose
level span contains a changed row (levels are longest-path depth, so each
vertex is written exactly once, in its own run — the carry entering a run
depends only on comp rows of the levels below it, making run-granular resume
bit-identical to a full sweep).
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Callable

import numpy as np

from ..core import ceft_jax, planners
from ..core.ceft import CeftResult, _finalize
from ..core.planners import Plan
from ..core.machine import Machine
from ..core.taskgraph import TaskGraph, from_edge_arrays, graph_fingerprint

_LOCK = threading.RLock()

# content-keyed graph store (absorbs ceft_jax's one-slot _REQUEST_GRAPH):
# equal edge arrays -> the same TaskGraph object, LRU-bounded so a router
# serving many DAG shapes keeps its recent working set instead of one slot
_GRAPH_STORE: OrderedDict[tuple, TaskGraph] = OrderedDict()
GRAPH_STORE_CAP = 64

# identity-keyed device-state store (absorbs ceft_jax's one-slot
# _GRAPH_STATE): id(graph) -> (graph, runs, srcs_pad, v_b, spans).  Entries
# hold a strong reference to the graph so the id cannot be recycled while
# the entry lives.
_DEVICE_STATE: OrderedDict[int, tuple] = OrderedDict()
DEVICE_STATE_CAP = 16


def graph_for(n: int, src, dst, data) -> TaskGraph:
    """The TaskGraph for edge arrays, content-keyed: equal arrays return the
    SAME object (so identity-keyed device state hits), racing builders agree
    on one winner."""
    src = np.ascontiguousarray(src, np.int32)
    dst = np.ascontiguousarray(dst, np.int32)
    data = np.ascontiguousarray(data, np.float64)
    key = (int(n), src.tobytes(), dst.tobytes(), data.tobytes())
    with _LOCK:
        g = _GRAPH_STORE.get(key)
        if g is not None:
            _GRAPH_STORE.move_to_end(key)
            return g
    g = from_edge_arrays(n, src, dst, data)
    with _LOCK:
        # first inserter wins: concurrent builders of the same key must all
        # hand out one object or the device-state identity cache splits
        g = _GRAPH_STORE.setdefault(key, g)
        _GRAPH_STORE.move_to_end(key)
        while len(_GRAPH_STORE) > GRAPH_STORE_CAP:
            _GRAPH_STORE.popitem(last=False)
    return g


def device_state(g: TaskGraph, segs=None):
    """(device runs, padded sources, v_b, run level spans) for one graph,
    identity-cached.  Built by :func:`ceft_jax._build_device_state`; this
    store only owns the lifetime."""
    key = id(g)
    with _LOCK:
        entry = _DEVICE_STATE.get(key)
        if entry is not None:
            _DEVICE_STATE.move_to_end(key)
            return entry[1], entry[2], entry[3], entry[4]
    built = (g,) + ceft_jax._build_device_state(g, segs=segs)
    with _LOCK:
        entry = _DEVICE_STATE.setdefault(key, built)
        _DEVICE_STATE.move_to_end(key)
        while len(_DEVICE_STATE) > DEVICE_STATE_CAP:
            _DEVICE_STATE.popitem(last=False)
    return entry[1], entry[2], entry[3], entry[4]


def machine_fingerprint(m: Machine) -> bytes:
    """Content digest of a machine (latencies, bandwidths, class counts)."""
    h = hashlib.sha1()
    for a in (m.L, m.bw, m.counts):
        a = np.ascontiguousarray(a)
        h.update(a.dtype.str.encode())
        h.update(np.asarray(a.shape, np.int64).tobytes())
        h.update(a.tobytes())
    return h.digest()


@dataclasses.dataclass
class PlanEntry:
    """One cached swept plan plus everything needed to resume it."""
    graph: TaskGraph
    machine: Machine
    comp32: np.ndarray            # (v, P) float32 plane the result was swept with
    result: CeftResult | Plan     # CeftResult (CSR path) or Plan (host path)
    carries: list                 # per-run carry snapshots (device arrays)
    classes: frozenset            # workload classes whose vertices the DAG holds
    dirty: bool = False           # advisory: a relevant delta landed since the sweep
    derived: dict = dataclasses.field(default_factory=dict)  # e.g. cpop memos


class PlanCache:
    """Content-keyed swept plans with reverse-index invalidation and
    dirty-frontier partial re-sweeps.

    ``plan`` statuses: ``"hit"`` (stored plane byte-equal — zero sweeps),
    ``"partial"`` (resumed at the lowest dirty fused run, reusing the cached
    carry for the clean prefix), ``"full"``.  All three return results
    bit-identical to a from-scratch sweep; see the module docstring for why.

    Thread-safe: one RLock serializes plan/invalidate, so concurrent
    ``observe()``/``maybe_replan`` callers can never read a torn reverse
    index or a half-updated entry.
    """

    def __init__(self, capacity: int = 128):
        self.capacity = int(capacity)
        self._lock = threading.RLock()
        self._plans: OrderedDict[tuple, PlanEntry] = OrderedDict()
        self._by_class: dict[object, set[tuple]] = {}
        self.counters = {"hits": 0, "full_sweeps": 0, "partial_sweeps": 0,
                         "invalidations": 0}

    # ------------------------------------------------------------------ keys
    @staticmethod
    def key(g: TaskGraph, m: Machine, slot=None,
            planner: str = "ceft_cpop") -> tuple:
        return (slot, planner, graph_fingerprint(g), machine_fingerprint(m))

    # -------------------------------------------------------------- planning
    def plan(
        self, g: TaskGraph, comp: np.ndarray, m: Machine, *,
        slot=None, classes=None, planner: str = "ceft_cpop",
        relax: Callable = ceft_jax.xla_edge_relax,
        store: bool = True,
    ) -> tuple[CeftResult | Plan, str, PlanEntry]:
        """Plan ``(g, comp, m)`` with the named planner, reusing as much
        cached work as the actual byte-level deltas allow.

        ``slot`` namespaces independent planes over the same graph/machine
        (the router's nominal vs degraded scenarios, the straggler baseline).
        ``classes`` registers the plan under those workload classes in the
        reverse index, so targeted :meth:`invalidate` calls can find it.
        ``planner`` selects the registered planner (``core/planners.py``):
        CEFT-consuming planners keep the batched CSR fast path below and
        return a :class:`CeftResult`; list-scheduling planners take a host
        path that returns a full :class:`Plan` — both still populate the
        cache, the reverse index, and the hit/full counters, and both verify
        a byte-equal cost plane before serving anything cached (a host plan
        is a deterministic function of the float32 plane, so byte-equality
        implies result-equality exactly as for the sweep).
        ``store=False`` makes the pass TRANSIENT: a miss still reads (and may
        resume from) the cached entry, but the fresh result is never stored —
        speculative pricing (the router's hedge re-plan) must not evict or
        overwrite the plans steady-state ticks are served from.
        Returns ``(result, status, entry)``.
        """
        comp32 = np.ascontiguousarray(comp, np.float32)
        spec = planners.get_planner(planner)
        k = self.key(g, m, slot, planner=planner)
        with self._lock:
            entry = self._plans.get(k)
            if entry is not None and entry.comp32.shape == comp32.shape and \
                    entry.comp32.tobytes() == comp32.tobytes():
                # byte-equal plane: the cached result IS the from-scratch
                # result, whatever advisory invalidations happened meanwhile
                entry.dirty = False
                self._plans.move_to_end(k)
                self.counters["hits"] += 1
                return entry.result, "hit", entry

            if not spec.uses_ceft:
                # host path: no sweep, no carries — the planner runs on the
                # float64 view of the float32 plane so a byte-equal plane
                # always reproduces the identical plan
                result = planners.plan(
                    planner, g, comp32.astype(np.float64), m)
                entry = PlanEntry(
                    graph=g, machine=m, comp32=comp32.copy(), result=result,
                    carries=[],
                    classes=frozenset(classes) if classes is not None
                    else frozenset(),
                )
                self.counters["full_sweeps"] += 1
                if store:
                    self._store(k, entry)
                return result, "full", entry

            inputs = ceft_jax.csr_device_inputs(g, comp32, m)
            _runs, _cp, _srcs, _L, _bw, _vb = inputs
            _, _, _, spans = device_state(g)
            resume_run = 0
            if entry is not None and entry.comp32.shape == comp32.shape:
                changed = np.nonzero(
                    (entry.comp32 != comp32).any(axis=1))[0]
                lo_level = int(g.level[changed].min())
                if lo_level >= 1:
                    # first run whose [lo, hi) span still contains dirty
                    # levels; runs below it (and the level-0 init) saw no
                    # comp change, so their cached carry is exact
                    for r, (lo, hi) in enumerate(spans):
                        if lo_level < hi:
                            resume_run = r
                            break
            if resume_run >= 1 and len(entry.carries) >= resume_run:
                carries = list(entry.carries[:resume_run])
                carry = ceft_jax.csr_sweep(
                    inputs, relax=relax, keep_carries=carries,
                    resume=(resume_run, entry.carries[resume_run - 1]))
                status = "partial"
                self.counters["partial_sweeps"] += 1
            else:
                carries = []
                carry = ceft_jax.csr_sweep(
                    inputs, relax=relax, keep_carries=carries)
                status = "full"
                self.counters["full_sweeps"] += 1
            ceft_arr, ptask, pproc = carry
            v = g.n
            result = _finalize(
                g,
                np.asarray(ceft_arr, np.float64)[:v],
                np.asarray(ptask)[:v],
                np.asarray(pproc)[:v],
            )
            entry = PlanEntry(
                graph=g, machine=m, comp32=comp32.copy(), result=result,
                carries=carries,
                classes=frozenset(classes) if classes is not None
                else frozenset(),
            )
            if store:
                self._store(k, entry)
            return result, status, entry

    def _store(self, k: tuple, entry: PlanEntry) -> None:
        old = self._plans.pop(k, None)
        if old is not None:
            self._unindex(k, old)
        self._plans[k] = entry
        for c in entry.classes:
            self._by_class.setdefault(c, set()).add(k)
        while len(self._plans) > self.capacity:
            ek, ev = self._plans.popitem(last=False)
            ev.dirty = True          # holders of the evicted entry must replan
            self._unindex(ek, ev)

    def _unindex(self, k: tuple, entry: PlanEntry) -> None:
        for c in entry.classes:
            keys = self._by_class.get(c)
            if keys is not None:
                keys.discard(k)
                if not keys:
                    del self._by_class[c]

    # ---------------------------------------------------------- invalidation
    def invalidate(self, *, wclass=None, engine: int | None = None,
                   machine_fp: bytes | None = None) -> int:
        """Mark affected plans dirty; returns how many flipped clean->dirty.

        ``wclass`` scopes through the reverse index to plans whose DAG
        contains that workload class — deliberately conservative (DAG
        containment, not path membership): a cost delta on an off-path class
        can MOVE the critical path, so only plans that cannot see the class
        at all may stay clean.  ``engine`` deltas (straggler slowdowns)
        rescale a whole comp column and dirty every plan.  ``machine_fp``
        scopes to plans swept over one machine snapshot — the engine pool's
        hook for a measured comm-plane delta: plans keyed by the superseded
        snapshot's fingerprint can never be served for the new machine (the
        fingerprint is part of the key), so dirtying them just stops holders
        short-circuiting on stale entries.  Advisory either way:
        :meth:`plan` re-verifies bytes before serving anything.
        """
        with self._lock:
            if wclass is not None:
                keys = list(self._by_class.get(wclass, ()))
            elif machine_fp is not None:
                keys = [k for k in self._plans if k[3] == machine_fp]
            elif engine is not None:
                keys = list(self._plans.keys())
            else:
                keys = list(self._plans.keys())
            n = 0
            for k in keys:
                e = self._plans.get(k)
                if e is not None and not e.dirty:
                    e.dirty = True
                    n += 1
            self.counters["invalidations"] += n
            return n

    # -------------------------------------------------------------- plumbing
    def snapshot(self) -> dict:
        with self._lock:
            return dict(self.counters)

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)


def clear_stores() -> None:
    """Drop the module-level graph / device-state stores (tests)."""
    with _LOCK:
        _GRAPH_STORE.clear()
        _DEVICE_STATE.clear()
