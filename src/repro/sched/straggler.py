"""Straggler mitigation: observe per-class step times, detect degradation via
EWMA drift, feed degraded costs back into CEFT-CPOP and re-plan.

This is the paper's heterogeneity story running *online*: a fleet that was
homogeneous at launch becomes heterogeneous when a slice degrades (thermal
throttling, a flaky ICI link, a preempted host).  CEFT's class-view cost model
absorbs the measurement directly (scale the class's comp column), and the
re-planned CEFT-CPOP schedule routes critical-path work away from the slow
class.  The re-planning sweeps run on the *batched CSR* formulation
(``ceft_jax_batch_csr``: shared segment tables, vmapped cost planes), so each
re-plan costs O(e·P²) device work — the paper's §5 bound — instead of the
padded dense sweep's O(levels·W·D·P²).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from ..core import ceft_cpop
from ..core.ceft_jax import ceft_batch_csr_results
from ..core.machine import Machine
from ..core.taskgraph import TaskGraph


@dataclasses.dataclass
class StragglerEvent:
    step: int
    device_class: int
    slowdown: float
    old_makespan: float
    new_makespan: float


def _content_key(g: TaskGraph, comp: np.ndarray, m: Machine) -> str:
    """Content hash of a (graph, costs, machine) planning problem.

    Keys the nominal-schedule cache by *value*, not object identity: a graph
    or cost array that is rebuilt between steps (same edges, fresh object —
    e.g. a re-built layer DAG) must still hit the cache.
    """
    h = hashlib.sha1()
    for a in (g.cindptr, g.cindices, g.cdata, comp, m.L, m.bw, m.counts):
        a = np.ascontiguousarray(a)
        h.update(a.dtype.str.encode())
        h.update(np.asarray(a.shape, np.int64).tobytes())
        h.update(a.tobytes())
    return h.hexdigest()


class StragglerMonitor:
    """EWMA per device class; replan when a class drifts > threshold."""

    def __init__(self, n_classes: int, alpha: float = 0.2, threshold: float = 1.3):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma = np.ones(n_classes) * np.nan
        self.baseline = np.ones(n_classes) * np.nan
        self.events: list[StragglerEvent] = []
        # nominal-schedule cache: the baseline CEFT-CPOP depends only on
        # (graph, comp, machine), not on the triggering event -- recomputing it
        # per event doubled the replan cost.  Keyed by content hash
        # (_content_key) so re-built but equal inputs hit the cache and
        # in-place mutation of comp / m.L / m.bw cannot serve a stale baseline.
        self._nominal_key: str | None = None
        self._nominal_sched = None

    def observe(self, class_times: np.ndarray) -> np.ndarray:
        """Update EWMAs; returns per-class slowdown factors (>= 1)."""
        new = np.isnan(self.ewma)
        self.ewma = np.where(new, class_times,
                             self.alpha * class_times + (1 - self.alpha) * self.ewma)
        self.baseline = np.where(np.isnan(self.baseline), self.ewma,
                                 np.minimum(self.baseline, self.ewma))
        return np.maximum(self.ewma / self.baseline, 1.0)

    def maybe_replan(self, step: int, g: TaskGraph, comp: np.ndarray, m: Machine,
                     class_times: np.ndarray):
        """Returns (schedule, event|None).  Schedules with degraded costs when
        any class trips the threshold; otherwise schedules with nominal costs.

        Both the degraded sweep and (when the cache is cold) the nominal
        baseline sweep go through one batched CSR dispatch sequence: the
        segment tables are shared, only the cost planes differ.
        """
        slow = self.observe(class_times)
        if (slow < self.threshold).all():
            return None, None
        degraded = comp * slow[None, :]
        key = _content_key(g, comp, m)
        planes = [degraded]
        if key != self._nominal_key:
            planes.append(comp)
        B = len(planes)
        Ls = np.repeat(np.asarray(m.L, np.float32)[None], B, 0)
        bws = np.repeat(np.asarray(m.bw, np.float32)[None], B, 0)
        results = ceft_batch_csr_results(g, np.stack(planes), Ls, bws)
        if key != self._nominal_key:
            self._nominal_sched = ceft_cpop(g, comp, m, results[1])
            self._nominal_key = key
        base = self._nominal_sched
        new = ceft_cpop(g, degraded, m, results[0])
        worst = int(np.argmax(slow))
        ev = StragglerEvent(step, worst, float(slow[worst]),
                            float(base.makespan), float(new.makespan))
        self.events.append(ev)
        return new, ev
