"""Straggler mitigation: observe per-class step times, detect degradation via
EWMA drift, feed degraded costs back into CEFT-CPOP and re-plan.

This is the paper's heterogeneity story running *online*: a fleet that was
homogeneous at launch becomes heterogeneous when a slice degrades (thermal
throttling, a flaky ICI link, a preempted host).  CEFT's class-view cost model
absorbs the measurement directly (scale the class's comp column), and the
re-planned CEFT-CPOP schedule routes critical-path work away from the slow
class.  The re-planning sweeps route through the unified plan cache
(``repro.sched.plancache``): fused CSR sweeps at O(e·P²) device work — the
paper's §5 bound — with quiet steps served as pure cache hits and changed
cost planes re-swept from their dirty frontier only.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from ..core import planners
from ..core.machine import Machine
from ..core.taskgraph import TaskGraph
from .plancache import PlanCache


@dataclasses.dataclass
class StragglerEvent:
    step: int
    device_class: int
    slowdown: float
    old_makespan: float
    new_makespan: float


# A lost worker is a FULLY-degraded class column: large enough that CEFT
# never maps work onto it, small enough to stay finite in float32 cost
# planes (inf would poison the min-plus sweep with NaNs).
LOST_SLOWDOWN = 1e6


class EwmaCostTable:
    """Online per-(workload-class, processor-class) cost model.

    One EWMA row of ``n_classes`` entries per hashable key — the serving
    router keys by request workload class (per-token generate rates), the
    training loop keys by layer class.  Shared between the router and the
    straggler machinery: :meth:`StragglerMonitor.observe` slowdown factors
    multiply onto these rows via :meth:`comp_matrix`'s ``scale`` argument,
    so a degraded processor class sheds critical-path work on the very next
    plan.

    Unobserved entries inside a partially-observed row fall back to the row's
    observed mean (neutral: new engines get explored, not written off at the
    ``default``); fully-unobserved rows fall back to ``default``.

    Thread-safe: the router executes micro-batches on per-engine worker
    threads, each feeding measurements back concurrently.

    Elastic: the class count may GROW while the table lives (the engine pool
    launches workers).  An update or degradation report for a class index the
    table has never seen widens every row (new entries NaN -> fallback rules
    above) instead of raising — a just-launched worker must be explorable,
    and a just-lost one degradable, without resetting learned rates.
    """

    def __init__(self, n_classes: int, alpha: float = 0.3, default: float = 1.0):
        self.n_classes = int(n_classes)
        self.alpha = float(alpha)
        self.default = float(default)
        self._rows: dict = {}
        self._lock = threading.Lock()
        self._listeners: list = []

    def ensure_classes(self, n: int) -> None:
        """Widen the table to ``n`` processor classes (no-op when already
        that wide); existing rows are padded with NaN (the explore default)."""
        with self._lock:
            self._ensure_locked(int(n))

    def _ensure_locked(self, n: int) -> None:
        if n <= self.n_classes:
            return
        pad = n - self.n_classes
        for key, row in self._rows.items():
            self._rows[key] = np.concatenate([row, np.full(pad, np.nan)])
        self.n_classes = n

    def reset_class(self, cls: int) -> None:
        """Forget every rate measured for one class column (a freed pool slot
        was revived by a DIFFERENT worker: its predecessor's rates are not
        evidence about it)."""
        with self._lock:
            if cls < self.n_classes:
                for row in self._rows.values():
                    row[cls] = np.nan

    def add_listener(self, fn) -> None:
        """Register ``fn(key, cls)`` to run after every :meth:`update` — the
        plan cache's invalidation hook (a cost delta dirties exactly the
        plans whose DAG contains ``key``).  Listeners run OUTSIDE the table
        lock: they take their own locks (the plan cache's), and nesting
        foreign locks under this one invites ordering deadlocks."""
        self._listeners.append(fn)

    def update(self, key, cls: int, value: float) -> None:
        with self._lock:
            # a measurement for an engine this table has never seen (a
            # just-launched pool worker) widens the table instead of raising
            self._ensure_locked(int(cls) + 1)
            row = self._rows.get(key)
            if row is None:
                row = self._rows[key] = np.full(self.n_classes, np.nan)
            row[cls] = (value if np.isnan(row[cls])
                        else self.alpha * value + (1 - self.alpha) * row[cls])
        for fn in self._listeners:
            fn(key, cls)

    def row(self, key) -> np.ndarray:
        """The (n_classes,) cost row for ``key``, NaN-free (see class doc)."""
        with self._lock:
            row = self._rows.get(key)
            if row is None or np.isnan(row).all():
                return np.full(self.n_classes, self.default)
            return np.where(np.isnan(row), np.nanmean(row), row)

    def comp_matrix(self, keys, scale=None) -> np.ndarray:
        """(len(keys), n_classes) cost plane in CEFT's comp-matrix shape,
        optionally column-scaled by per-class slowdown factors."""
        out = np.stack([self.row(k) for k in keys])
        if scale is not None:
            out = out * np.asarray(scale, np.float64)[None, :]
        return out


class StragglerMonitor:
    """EWMA per device class; replan when a class drifts > threshold.

    Elastic (the engine-pool contract): the class count grows on demand —
    a slowdown report or loss mark for a class the monitor has never seen
    (a just-launched or just-lost worker) widens the arrays and registers a
    degraded column instead of raising.  A LOST class reports
    :data:`LOST_SLOWDOWN` until revived, so the batched nominal+degraded
    re-plan that already handles stragglers covers failover unchanged.
    """

    def __init__(self, n_classes: int, alpha: float = 0.2, threshold: float = 1.3,
                 plancache: PlanCache | None = None,
                 planner: str = "ceft_cpop"):
        self.alpha = alpha
        self.threshold = threshold
        # nominal + degraded re-planning is parameterized by registry name —
        # fail fast on typos, before the first maybe_replan
        self.planner = planners.get_planner(planner).name
        self.ewma = np.ones(n_classes) * np.nan
        self.baseline = np.ones(n_classes) * np.nan
        self.lost = np.zeros(n_classes, bool)
        self.events: list[StragglerEvent] = []
        # nominal-schedule caching is a thin view over the unified plan cache
        # (repro.sched.plancache): swept plans are content-keyed there by
        # (graph, cost plane, machine) value, so re-built but equal inputs
        # hit and in-place mutation of comp / m.L / m.bw cannot serve a
        # stale baseline (plan() byte-compares the stored plane).  The
        # CEFT-CPOP mapping is memoized on the plan entry (entry.derived),
        # which plan() resets whenever the plane actually changed.
        self.plancache = plancache if plancache is not None else PlanCache()
        self._nominal_sched = None

    def _cpop(self, g: TaskGraph, comp: np.ndarray, m: Machine, *, slot: str):
        """Swept plan + memoized realized mapping through the plan cache.

        For CEFT-consuming planners the cache returns the CSR sweep's
        CeftResult and the realized schedule is memoized per entry; for
        host-path planners the cached result already IS the full Plan."""
        res, _status, entry = self.plancache.plan(
            g, comp, m, slot=slot, planner=self.planner)
        sched = entry.derived.get("sched")
        if sched is None:
            sched = entry.derived["sched"] = planners.realize(
                self.planner, g, comp, m, res)
        return sched

    def ensure_classes(self, n: int) -> None:
        """Widen to ``n`` classes (never shrinks): new columns start
        unobserved (NaN EWMA/baseline) and healthy (not lost)."""
        n = int(n)
        if n <= len(self.ewma):
            return
        pad = n - len(self.ewma)
        self.ewma = np.concatenate([self.ewma, np.full(pad, np.nan)])
        self.baseline = np.concatenate([self.baseline, np.full(pad, np.nan)])
        self.lost = np.concatenate([self.lost, np.zeros(pad, bool)])

    def slowdowns(self) -> np.ndarray:
        """Current per-class slowdown factors (>= 1): unobserved columns are
        nominal (1.0), lost columns are :data:`LOST_SLOWDOWN`."""
        with np.errstate(invalid="ignore"):
            s = np.where(np.isnan(self.ewma) | np.isnan(self.baseline), 1.0,
                         np.maximum(self.ewma / self.baseline, 1.0))
        return np.where(self.lost, LOST_SLOWDOWN, s)

    def report(self, cls: int, slowdown: float) -> np.ndarray:
        """Register a degraded column directly — the path for slowdown
        reports about an engine the monitor has never seen (a just-launched
        or just-lost pool worker), which must grow the arrays instead of
        raising (ISSUE 7 regression).  Returns the slowdown factors."""
        cls = int(cls)
        self.ensure_classes(cls + 1)
        if np.isnan(self.baseline[cls]):
            self.baseline[cls] = 1.0
        self.ewma[cls] = self.baseline[cls] * float(slowdown)
        return self.slowdowns()

    def report_overdue(self, cls: int,
                       observed_slowdown: float | None = None) -> np.ndarray:
        """A deadline-watchdog strike: the engine blew its plan-derived
        budget.  Registers at least a threshold-tripping slowdown — never
        *reducing* an already-degraded column, and leaving LOST columns
        alone — so the very next plan sheds critical-path work off the
        offender.  Returns the slowdown factors."""
        cls = int(cls)
        self.ensure_classes(cls + 1)
        if self.lost[cls]:
            return self.slowdowns()
        want = max(self.threshold, float(self.slowdowns()[cls]))
        if observed_slowdown is not None:
            want = max(want, float(observed_slowdown))
        return self.report(cls, want)

    def mark_lost(self, cls: int) -> np.ndarray:
        """A worker died: its class column becomes fully degraded (grows the
        arrays for never-observed classes).  Returns the slowdown factors."""
        cls = int(cls)
        self.ensure_classes(cls + 1)
        self.lost[cls] = True
        return self.slowdowns()

    def revive(self, cls: int) -> None:
        """A freed slot was relaunched: clear the lost flag and forget the
        previous worker's timing evidence for that column."""
        cls = int(cls)
        self.ensure_classes(cls + 1)
        self.lost[cls] = False
        self.ewma[cls] = np.nan
        self.baseline[cls] = np.nan

    def observe(self, class_times: np.ndarray) -> np.ndarray:
        """Update EWMAs; returns per-class slowdown factors (>= 1).

        ``class_times`` may be wider than the monitor (just-launched
        workers: the arrays grow) or narrower (times for a prefix of the
        classes: the unmeasured tail keeps its current estimate)."""
        class_times = np.asarray(class_times, np.float64)
        self.ensure_classes(len(class_times))
        if len(class_times) < len(self.ewma):
            tail = self.ewma[len(class_times):]
            class_times = np.concatenate(
                [class_times, np.where(np.isnan(tail), 1.0, tail)])
        new = np.isnan(self.ewma)
        self.ewma = np.where(new, class_times,
                             self.alpha * class_times + (1 - self.alpha) * self.ewma)
        self.baseline = np.where(np.isnan(self.baseline), self.ewma,
                                 np.minimum(self.baseline, self.ewma))
        return self.slowdowns()

    def maybe_replan(self, step: int, g: TaskGraph, comp: np.ndarray, m: Machine,
                     class_times: np.ndarray):
        """Returns (schedule, event|None).  Schedules with degraded costs when
        any class trips the threshold; otherwise schedules with nominal costs
        (the cached nominal schedule, computed on first call).

        Both the nominal baseline and the degraded scenario go through the
        unified plan cache: the graph's device-side segment tables are built
        once, a quiet step with unchanged costs is a pure cache hit (zero
        sweeps), and a changed plane re-sweeps only from its dirty frontier.
        """
        slow = self.observe(class_times)
        if (slow < self.threshold).all():
            # Below threshold the docstring always promised the *nominal*
            # schedule, but this path once returned (None, None) and never
            # warmed the nominal cache -- the first straggler event then paid
            # for both sweeps at the worst moment (ISSUE 5 regression fix).
            self._nominal_sched = self._cpop(g, comp, m, slot="nominal")
            return self._nominal_sched, None
        base = self._nominal_sched = self._cpop(g, comp, m, slot="nominal")
        degraded = comp * slow[None, :]
        new = self._cpop(g, degraded, m, slot="degraded")
        worst = int(np.argmax(slow))
        ev = StragglerEvent(step, worst, float(slow[worst]),
                            float(base.makespan), float(new.makespan))
        self.events.append(ev)
        return new, ev
