"""Straggler mitigation: observe per-class step times, detect degradation via
EWMA drift, feed degraded costs back into CEFT-CPOP and re-plan.

This is the paper's heterogeneity story running *online*: a fleet that was
homogeneous at launch becomes heterogeneous when a slice degrades (thermal
throttling, a flaky ICI link, a preempted host).  CEFT's class-view cost model
absorbs the measurement directly (scale the class's comp column), and the
re-planned CEFT-CPOP schedule routes critical-path work away from the slow
class.  The re-planning sweeps run on the *batched CSR* formulation
(``ceft_jax_batch_csr``: shared segment tables, vmapped cost planes), so each
re-plan costs O(e·P²) device work — the paper's §5 bound — instead of the
padded dense sweep's O(levels·W·D·P²).
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading

import numpy as np

from ..core import ceft_cpop
from ..core.ceft_jax import ceft_batch_csr_results
from ..core.machine import Machine
from ..core.taskgraph import TaskGraph


@dataclasses.dataclass
class StragglerEvent:
    step: int
    device_class: int
    slowdown: float
    old_makespan: float
    new_makespan: float


class EwmaCostTable:
    """Online per-(workload-class, processor-class) cost model.

    One EWMA row of ``n_classes`` entries per hashable key — the serving
    router keys by request workload class (per-token generate rates), the
    training loop keys by layer class.  Shared between the router and the
    straggler machinery: :meth:`StragglerMonitor.observe` slowdown factors
    multiply onto these rows via :meth:`comp_matrix`'s ``scale`` argument,
    so a degraded processor class sheds critical-path work on the very next
    plan.

    Unobserved entries inside a partially-observed row fall back to the row's
    observed mean (neutral: new engines get explored, not written off at the
    ``default``); fully-unobserved rows fall back to ``default``.

    Thread-safe: the router executes micro-batches on per-engine worker
    threads, each feeding measurements back concurrently.
    """

    def __init__(self, n_classes: int, alpha: float = 0.3, default: float = 1.0):
        self.n_classes = int(n_classes)
        self.alpha = float(alpha)
        self.default = float(default)
        self._rows: dict = {}
        self._lock = threading.Lock()

    def update(self, key, cls: int, value: float) -> None:
        with self._lock:
            row = self._rows.get(key)
            if row is None:
                row = self._rows[key] = np.full(self.n_classes, np.nan)
            row[cls] = (value if np.isnan(row[cls])
                        else self.alpha * value + (1 - self.alpha) * row[cls])

    def row(self, key) -> np.ndarray:
        """The (n_classes,) cost row for ``key``, NaN-free (see class doc)."""
        with self._lock:
            row = self._rows.get(key)
            if row is None or np.isnan(row).all():
                return np.full(self.n_classes, self.default)
            return np.where(np.isnan(row), np.nanmean(row), row)

    def comp_matrix(self, keys, scale=None) -> np.ndarray:
        """(len(keys), n_classes) cost plane in CEFT's comp-matrix shape,
        optionally column-scaled by per-class slowdown factors."""
        out = np.stack([self.row(k) for k in keys])
        if scale is not None:
            out = out * np.asarray(scale, np.float64)[None, :]
        return out


def _content_key(g: TaskGraph, comp: np.ndarray, m: Machine) -> str:
    """Content hash of a (graph, costs, machine) planning problem.

    Keys the nominal-schedule cache by *value*, not object identity: a graph
    or cost array that is rebuilt between steps (same edges, fresh object —
    e.g. a re-built layer DAG) must still hit the cache.
    """
    h = hashlib.sha1()
    for a in (g.cindptr, g.cindices, g.cdata, comp, m.L, m.bw, m.counts):
        a = np.ascontiguousarray(a)
        h.update(a.dtype.str.encode())
        h.update(np.asarray(a.shape, np.int64).tobytes())
        h.update(a.tobytes())
    return h.hexdigest()


class StragglerMonitor:
    """EWMA per device class; replan when a class drifts > threshold."""

    def __init__(self, n_classes: int, alpha: float = 0.2, threshold: float = 1.3):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma = np.ones(n_classes) * np.nan
        self.baseline = np.ones(n_classes) * np.nan
        self.events: list[StragglerEvent] = []
        # nominal-schedule cache: the baseline CEFT-CPOP depends only on
        # (graph, comp, machine), not on the triggering event -- recomputing it
        # per event doubled the replan cost.  Keyed by content hash
        # (_content_key) so re-built but equal inputs hit the cache and
        # in-place mutation of comp / m.L / m.bw cannot serve a stale baseline.
        self._nominal_key: str | None = None
        self._nominal_sched = None

    def observe(self, class_times: np.ndarray) -> np.ndarray:
        """Update EWMAs; returns per-class slowdown factors (>= 1)."""
        new = np.isnan(self.ewma)
        self.ewma = np.where(new, class_times,
                             self.alpha * class_times + (1 - self.alpha) * self.ewma)
        self.baseline = np.where(np.isnan(self.baseline), self.ewma,
                                 np.minimum(self.baseline, self.ewma))
        return np.maximum(self.ewma / self.baseline, 1.0)

    def maybe_replan(self, step: int, g: TaskGraph, comp: np.ndarray, m: Machine,
                     class_times: np.ndarray):
        """Returns (schedule, event|None).  Schedules with degraded costs when
        any class trips the threshold; otherwise schedules with nominal costs
        (the cached nominal schedule, computed on first call).

        Both the degraded sweep and (when the cache is cold) the nominal
        baseline sweep go through one batched CSR dispatch sequence: the
        segment tables are shared, only the cost planes differ.
        """
        slow = self.observe(class_times)
        # content-hashed on every call, including quiet steps: an identity
        # memo would be cheaper but could serve a stale baseline after
        # in-place mutation of comp / m.L / m.bw (the guarantee _content_key
        # exists for); the planning arrays are KB-scale, so the hash is
        # microseconds against a training step
        key = _content_key(g, comp, m)
        if (slow < self.threshold).all():
            # Below threshold the docstring always promised the *nominal*
            # schedule, but this path returned (None, None) and never warmed
            # the nominal cache -- the first straggler event then paid for
            # both sweeps at the worst moment (ISSUE 5 regression fix).
            if key != self._nominal_key:
                results = ceft_batch_csr_results(
                    g, np.asarray(comp, np.float32)[None],
                    np.asarray(m.L, np.float32)[None],
                    np.asarray(m.bw, np.float32)[None])
                self._nominal_sched = ceft_cpop(g, comp, m, results[0])
                self._nominal_key = key
            return self._nominal_sched, None
        degraded = comp * slow[None, :]
        planes = [degraded]
        if key != self._nominal_key:
            planes.append(comp)
        B = len(planes)
        Ls = np.repeat(np.asarray(m.L, np.float32)[None], B, 0)
        bws = np.repeat(np.asarray(m.bw, np.float32)[None], B, 0)
        results = ceft_batch_csr_results(g, np.stack(planes), Ls, bws)
        if key != self._nominal_key:
            self._nominal_sched = ceft_cpop(g, comp, m, results[1])
            self._nominal_key = key
        base = self._nominal_sched
        new = ceft_cpop(g, degraded, m, results[0])
        worst = int(np.argmax(slow))
        ev = StragglerEvent(step, worst, float(slow[worst]),
                            float(base.makespan), float(new.makespan))
        self.events.append(ev)
        return new, ev
