"""Straggler mitigation: observe per-class step times, detect degradation via
EWMA drift, feed degraded costs back into CEFT-CPOP and re-plan.

This is the paper's heterogeneity story running *online*: a fleet that was
homogeneous at launch becomes heterogeneous when a slice degrades (thermal
throttling, a flaky ICI link, a preempted host).  CEFT's class-view cost model
absorbs the measurement directly (scale the class's comp column), and the
re-planned CEFT-CPOP schedule routes critical-path work away from the slow
class -- with vectorized/batched CEFT (ceft_jax) cheap enough to run inside
the training loop's control plane.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import ceft, ceft_cpop
from ..core.machine import Machine
from ..core.taskgraph import TaskGraph


@dataclasses.dataclass
class StragglerEvent:
    step: int
    device_class: int
    slowdown: float
    old_makespan: float
    new_makespan: float


class StragglerMonitor:
    """EWMA per device class; replan when a class drifts > threshold."""

    def __init__(self, n_classes: int, alpha: float = 0.2, threshold: float = 1.3):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma = np.ones(n_classes) * np.nan
        self.baseline = np.ones(n_classes) * np.nan
        self.events: list[StragglerEvent] = []
        # nominal-schedule cache: the baseline CEFT-CPOP depends only on
        # (graph, comp, machine), not on the triggering event -- recomputing it
        # per event doubled the replan cost.  The graph is keyed by identity
        # (held so its id cannot be recycled); cost arrays are compared by
        # value (copies held) so in-place mutation of comp / m.L / m.bw cannot
        # serve a stale baseline.
        self._nominal_key: tuple | None = None
        self._nominal_sched = None

    def _nominal(self, g: TaskGraph, comp: np.ndarray, m: Machine):
        stale = (
            self._nominal_key is None
            or self._nominal_key[0] is not g
            or not np.array_equal(self._nominal_key[1], comp)
            or not np.array_equal(self._nominal_key[2], m.L)
            or not np.array_equal(self._nominal_key[3], m.bw)
        )
        if stale:
            self._nominal_sched = ceft_cpop(g, comp, m, ceft(g, comp, m))
            self._nominal_key = (g, comp.copy(), np.copy(m.L), np.copy(m.bw))
        return self._nominal_sched

    def observe(self, class_times: np.ndarray) -> np.ndarray:
        """Update EWMAs; returns per-class slowdown factors (>= 1)."""
        new = np.isnan(self.ewma)
        self.ewma = np.where(new, class_times,
                             self.alpha * class_times + (1 - self.alpha) * self.ewma)
        self.baseline = np.where(np.isnan(self.baseline), self.ewma,
                                 np.minimum(self.baseline, self.ewma))
        return np.maximum(self.ewma / self.baseline, 1.0)

    def maybe_replan(self, step: int, g: TaskGraph, comp: np.ndarray, m: Machine,
                     class_times: np.ndarray):
        """Returns (schedule, event|None).  Schedules with degraded costs when
        any class trips the threshold; otherwise schedules with nominal costs."""
        slow = self.observe(class_times)
        if (slow < self.threshold).all():
            return None, None
        degraded = comp * slow[None, :]
        base = self._nominal(g, comp, m)
        new = ceft_cpop(g, degraded, m, ceft(g, degraded, m))
        worst = int(np.argmax(slow))
        ev = StragglerEvent(step, worst, float(slow[worst]),
                            float(base.makespan), float(new.makespan))
        self.events.append(ev)
        return new, ev
