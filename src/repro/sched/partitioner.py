"""CEFT-guided pipeline partitioner (the paper's technique as a first-class
runtime feature).

Given an architecture x shape cell and a heterogeneous fleet, build the layer
DAG, run CEFT for the true critical path + its partial assignment (the makespan
lower bound and the class each stage *wants*), schedule with CEFT-CPOP, and
collapse the per-layer assignment into contiguous pipeline stages.  CPOP and
HEFT plans are produced for comparison -- the paper's Table-3 experiment
replayed on real model graphs.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig, ShapeCell
from ..core import planners, validate_schedule
from .layer_dag import DEFAULT_FLEET, build_layer_dag


@dataclasses.dataclass
class Stage:
    start_layer: int          # index into the DAG's node list
    end_layer: int            # inclusive
    device_class: str


@dataclasses.dataclass
class PipelinePlan:
    stages: list[Stage]
    cpl: float                # CEFT critical-path length (makespan lower bound)
    makespan: float           # CEFT-CPOP schedule makespan
    makespan_cpop: float
    makespan_heft: float
    assignment: dict[int, int]
    labels: list[str]

    @property
    def speedup_vs_cpop(self) -> float:
        return self.makespan_cpop / self.makespan


def plan_pipeline(cfg: ArchConfig, cell: ShapeCell, fleet=None) -> PipelinePlan:
    fleet = fleet or DEFAULT_FLEET
    g, comp, m, labels = build_layer_dag(cfg, cell, fleet)
    # all three plans come from the registry (sched/ never imports scheduler
    # functions directly); ceft_cpop's Plan carries the CEFT path + cpl
    p_ours = planners.plan("ceft_cpop", g, comp, m)
    p_cpop = planners.plan("cpop", g, comp, m)
    p_heft = planners.plan("heft", g, comp, m)
    for s in (p_ours, p_cpop, p_heft):
        validate_schedule(s, g, comp, m)

    # collapse the CEFT path assignment into contiguous stages
    names = [c.name for c in fleet]
    stages: list[Stage] = []
    for task, cls in p_ours.path:
        if stages and names[cls] == stages[-1].device_class:
            stages[-1].end_layer = task
        else:
            stages.append(Stage(task, task, names[cls]))
    return PipelinePlan(
        stages=stages,
        cpl=p_ours.cpl,
        makespan=p_ours.makespan,
        makespan_cpop=p_cpop.makespan,
        makespan_heft=p_heft.makespan,
        assignment=p_ours.assignment,
        labels=labels,
    )
