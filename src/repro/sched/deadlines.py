"""Backward deadline propagation over a planned CEFT schedule (ISSUE 9).

The paper's plan is deliberately *partial*: CEFT assigns processor classes
only to the critical path, and the mutual-inclusivity claim is about that
path being consistent with its own partial schedule.  Serving needs the
complement.  Once every task is bound to a class — path tasks to the path's
own partial assignment, off-path tasks to their earliest-finish class, the
same completion rule ``Router._choose`` dispatches with — the plan implies a
full schedule, and a request SLO can be walked *backward* through it: every
task gets a latest start/finish such that the request can still meet its
deadline, and ``latest_start - planned_start`` is the task's **slack**, the
quantity the router spends deliberately (shed the most-slack work off a
degraded engine first; arm watchdog budgets from latest-finish instead of a
flat multiple of the planned span — the multi-criteria latency/throughput
trade of Benoit, Rehn-Sonigo & Robert run per-tick).

Both passes are classic CPM over the *mapped scalar graph*: fix the class
map ``a(t)``, weight each task ``w(t) = comp[t, a(t)]`` and each edge
``comm(data, a(parent), a(child))`` (zero when co-located, exactly the
DP's own comm rule), then

    planned_start(t) = max over parents k of planned_finish(k) + comm(k, t)
    latest_finish(t) = min over children c of latest_start(c) - comm(t, c)

with ``latest_finish(sink) = slo`` (default: the mapped makespan).

Consistency with the CEFT plan (the properties tests/test_deadlines.py
checks over the graph zoo):

* ``planned_finish(t) >= ceft[t, a(t)]`` for every task (induction: the DP's
  min over a parent's classes is never above the mapped parent's own class),
  hence ``makespan >= res.cpl``.
* With ``slo = makespan``, ``slack >= 0`` everywhere and the zero-slack set
  is exactly the mapped schedule's critical path (CPM duality).
* Whenever ``makespan == res.cpl`` — i.e. the partial schedule extends to a
  full one without any off-path parent pushing a path task — every task on
  ``res.path`` has zero slack: the paper's critical path IS the zero-slack
  chain.  A strictly larger makespan is the interesting diagnostic case: the
  *partial* schedule was self-consistent but binding the off-path tasks
  lengthened some other chain past it, and the propagation reports slack
  relative to what will actually run, not what the DP priced.

Latest times are affine in the horizon: ``latest_*(slo') = latest_*(slo) +
(slo' - slo)`` when every sink shares the horizon.  Callers with a cached
schedule therefore shift by ``rem - makespan`` (remaining SLO budget minus
the planned makespan) instead of re-propagating — ``Router._deadline_view``
memoizes one propagation per plan entry under ``PlanEntry.derived`` and the
watchdog budgets are the shifted latest-finish values.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.ceft import CeftResult
from ..core.machine import Machine
from ..core.taskgraph import TaskGraph


def plan_classes(res: CeftResult) -> np.ndarray:
    """Per-task class under the plan: critical-path tasks keep the path's own
    partial assignment, every other task takes its earliest-finish class
    (argmin of its DP row — the same rule the router's dispatch uses before
    load balancing)."""
    cls = np.argmin(res.ceft, axis=1).astype(np.int64)
    for t, p in res.assignment.items():
        cls[t] = p
    return cls


@dataclasses.dataclass(frozen=True)
class DeadlineSchedule:
    """Forward + backward CPM pass over the mapped scalar graph.

    All times are seconds on the plan's own clock (tick start = 0); absolute
    deadlines are obtained by shifting — see :meth:`latest_finish_for`.
    """
    classes: np.ndarray         # (v,) mapped class per task
    planned_start: np.ndarray   # (v,) earliest start under the mapping
    planned_finish: np.ndarray  # (v,) planned_start + mapped comp
    latest_start: np.ndarray    # (v,) latest start still meeting the slo
    latest_finish: np.ndarray   # (v,) latest_start + mapped comp
    slack: np.ndarray           # (v,) latest_start - planned_start
    makespan: float             # mapped-schedule makespan (max planned_finish)
    cpl: float                  # the CEFT plan's critical-path length
    slo: float                  # the horizon the backward pass used

    @property
    def feasible(self) -> bool:
        """True when the planned schedule meets the slo (no negative slack)."""
        return bool((self.slack >= -1e-9 * max(1.0, abs(self.slo))).all())

    def critical(self, eps: float = 1e-9) -> np.ndarray:
        """Zero-slack mask — the mapped schedule's critical path."""
        return self.slack <= eps * max(1.0, abs(self.makespan))

    def latest_finish_for(self, task: int, remaining: float) -> float:
        """Latest finish (seconds from now) for ``task`` when its request has
        ``remaining`` seconds of SLO budget left: the affine shift
        ``latest_finish + (remaining - slo)``, no re-propagation needed."""
        return float(self.latest_finish[task]) + (float(remaining) - self.slo)


def propagate_deadlines(g: TaskGraph, comp: np.ndarray, m: Machine,
                        res: CeftResult, *, slo: float | None = None,
                        sink_slos: dict[int, float] | None = None,
                        ) -> DeadlineSchedule:
    """Walk the CEFT schedule forward then backward on its mapped classes.

    ``slo`` is the latest-finish horizon handed to every sink (default: the
    mapped makespan, which makes ``slack`` the schedule's intrinsic slack);
    ``sink_slos`` overrides it per vertex (min-combined when a vertex gets
    both) — the router uses this for per-class decode deadlines.  Vertex ids
    must be a topological order (every TaskGraph guarantees this)."""
    v = g.n
    cls = plan_classes(res)
    if comp.shape[0] != v:
        raise ValueError(f"comp has {comp.shape[0]} rows for {v} tasks")
    w = np.asarray(comp, np.float64)[np.arange(v), cls]

    ps = np.zeros(v, np.float64)
    for t in range(v):
        parents = g.parents(t)
        if parents.size:
            pk = cls[parents]
            comm = np.where(pk == cls[t], 0.0,
                            m.L[pk] + g.parent_data(t) / m.bw[pk, cls[t]])
            ps[t] = float(np.max(ps[parents] + w[parents] + comm))
    pf = ps + w
    makespan = float(pf[g.sinks].max()) if v else 0.0

    horizon = makespan if slo is None else float(slo)
    lf = np.full(v, np.inf)
    lf[g.sinks] = horizon
    if sink_slos:
        for t, d in sink_slos.items():
            lf[int(t)] = min(lf[int(t)], float(d))
    for t in reversed(range(v)):
        children = g.children(t)
        if children.size:
            ck = cls[children]
            comm = np.where(ck == cls[t], 0.0,
                            m.L[cls[t]] + g.child_data(t) / m.bw[cls[t], ck])
            lf[t] = min(lf[t], float(np.min(lf[children] - w[children] - comm)))
    ls = lf - w

    return DeadlineSchedule(
        classes=cls, planned_start=ps, planned_finish=pf,
        latest_start=ls, latest_finish=lf, slack=ls - ps,
        makespan=makespan, cpl=float(res.cpl), slo=horizon)
