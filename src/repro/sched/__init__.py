"""repro.sched — the paper's algorithm as the runtime's scheduling brain."""
from .deadlines import DeadlineSchedule, plan_classes, propagate_deadlines
from .layer_dag import DEFAULT_FLEET, DeviceClass, build_layer_dag, fleet_machine
from .partitioner import PipelinePlan, Stage, plan_pipeline
from .plancache import PlanCache, PlanEntry
from .straggler import (LOST_SLOWDOWN, EwmaCostTable, StragglerEvent,
                        StragglerMonitor)
__all__ = ["DEFAULT_FLEET", "DeadlineSchedule", "DeviceClass", "EwmaCostTable",
           "LOST_SLOWDOWN", "PipelinePlan", "PlanCache", "PlanEntry", "Stage",
           "StragglerEvent", "StragglerMonitor", "build_layer_dag",
           "fleet_machine", "plan_classes", "plan_pipeline",
           "propagate_deadlines"]
