"""Training launcher: any assigned architecture (smoke scale on CPU; the same
code path drives the production meshes on real fleets).

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --steps 50
"""
import argparse

from .. import configs as C
from ..configs.base import ShapeCell
from ..models.common import profile_names
from ..train import Trainer, TrainerConfig
from .mesh import make_test_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCHS, default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest valid checkpoint before training")
    ap.add_argument("--profile", default="opt1", choices=profile_names(),
                    help="sharding profile, scoped to this trainer")
    args = ap.parse_args()

    cfg = C.get(args.arch, smoke=args.smoke)
    cell = ShapeCell("cli", seq_len=args.seq, global_batch=args.batch, kind="train")
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, log_every=max(1, args.steps // 20),
                         profile=args.profile)
    tr = Trainer(cfg, cell, tcfg, make_test_mesh)
    for m in tr.run():
        print(m, flush=True)


if __name__ == "__main__":
    main()
