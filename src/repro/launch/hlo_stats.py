"""Post-SPMD HLO text analysis: collective inventory and byte counts.

``compiled.cost_analysis()`` has no collective figures, so we parse the
optimized per-device HLO (``compiled.as_text()``): every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op's result
bytes, execution-weighted by the trip counts of enclosing ``while`` loops
(jax.lax.scan lowers to while; the trip count is recovered from the largest
integer constant in the loop's condition computation -- exact for
scan-generated loops).

Byte convention (ring cost model): per-device link bytes ~= result bytes x
factor, factor 2 for all-reduce (reduce-scatter + all-gather phases), 1
otherwise.  ``collective_bytes`` is the global figure (x n_devices), matching
the roofline term collective_bytes / (chips x link_bw).
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_FACTOR = {"all-reduce": 2.0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)[ ]*\(", re.M)


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
        break  # first shape in the segment is the result type
    return total


def _split_computations(text: str) -> dict[str, str]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and ("->" in line or line.rstrip().endswith("{")):
            cur = m.group(1)
            comps[cur] = []
        if cur is not None:
            comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def _trip_count(cond_text: str) -> int:
    ints = [int(x) for x in re.findall(r"constant\((\d+)\)", cond_text)]
    return max(ints) if ints else 1


def collective_stats(hlo_text: str, n_devices: int) -> dict:
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            entry = m.group(1) if m else None
            break

    # local (unweighted) collective bytes + call/while edges per computation
    local: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for name, body in comps.items():
        for line in body.splitlines():
            for kw in COLLECTIVES:
                if f" {kw}(" in line or f"{kw}-start(" in line:
                    b = _shape_bytes(line.split("=", 1)[-1])
                    local[name] += b * _FACTOR.get(kw, 1.0)
                    counts[kw] += 1
            mw = re.search(r"while\(.*?condition=%?([\w.\-]+),.*?body=%?([\w.\-]+)", line)
            if not mw:  # attribute order can vary
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                mw = (mc, mb) if (mb and mc and "while(" in line) else None
                if mw:
                    cond, bod = mc.group(1), mb.group(1)
                    edges[name].append((bod, _trip_count(comps.get(cond, ""))))
                continue
            cond, bod = mw.group(1), mw.group(2)
            edges[name].append((bod, _trip_count(comps.get(cond, ""))))
        for mm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", body):
            callee = mm.group(1)
            if callee in comps and callee != name:
                edges[name].append((callee, 1))

    def weighted(name: str, seen: tuple = ()) -> float:
        if name not in comps or name in seen:
            return 0.0
        total = local.get(name, 0.0)
        for callee, mult in edges.get(name, []):
            total += mult * weighted(callee, seen + (name,))
        return total

    per_device = weighted(entry) if entry else sum(local.values())
    flat = sum(local.values())
    return {
        "collective_bytes": per_device * n_devices,
        "collective_bytes_per_device": per_device,
        "collective_bytes_flat": flat * n_devices,
        "op_counts": dict(counts),
    }
