"""Mesh construction for the production topology.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import;
smoke tests and benches see the 1 real CPU device.

All version-sensitive mesh APIs live in repro.substrate; this module only
picks shapes.
"""
from __future__ import annotations

import jax

from ..substrate import make_mesh, mesh_axis_sizes  # noqa: F401  (re-export)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).  Multi-pod: 2 pods of 256
    (pod, data, model); the pod axis carries data parallelism over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (CPU smoke tests)."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return make_mesh((n // model, model), ("data", "model"))
