import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init.  Tests/benches never import this module, so they keep
# seeing the single real CPU device.
if os.environ.get("REPRO_DRYRUN_DEVICES"):  # test hook: smaller fake fleets
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell against the production topology,
record memory/cost/collective analysis for §Dry-run and §Roofline.

  python -m repro.launch.dryrun --arch glm4-9b --cell train_4k --mesh single
  python -m repro.launch.dryrun --all --out experiments/dryrun      # driver
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs as C
from ..models.common import (profile_names, resolve_spec, sharding_profile,
                             tree_map_pspec)
from ..models.model import build
from ..substrate import (
    compiled_cost_analysis,
    make_mesh as substrate_make_mesh,
    mesh_context,
)
from .hlo_stats import collective_stats
from .mesh import mesh_axis_sizes
from .steps import (
    DecodeStep,
    TrainStep,
    abstract_cache,
    abstract_state,
    build_train,
    input_shardings,
    make_optimizer,
)
from jax.sharding import NamedSharding, PartitionSpec


def make_mesh(kind: str, smoke: bool = False):
    devs = np.asarray(jax.devices())
    if kind == "moe":  # EP-aligned single-pod mesh (see PROFILES["moe_ep"])
        shape, axes = ((2, 2, 2), ("data", "expert", "tp")) if smoke else \
                      ((16, 8, 2), ("data", "expert", "tp"))
    elif smoke:
        shape = (2, 2, 2) if kind == "multi" else (4, 2)
        axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    else:
        shape = (2, 16, 16) if kind == "multi" else (16, 16)
        axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    return substrate_make_mesh(shape, axes, devices=devs)


def analytic_bytes_per_device(spec_tree, mesh, dtype_override=None) -> int:
    ms = mesh_axis_sizes(mesh)
    total = 0

    def add(_, p):
        nonlocal total
        spec = resolve_spec(p.shape, p.logical, ms)
        shard = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                shard *= ms[ax]
        size = int(np.prod(p.shape)) * jnp.dtype(dtype_override or p.dtype).itemsize
        total += size // shard
        return None

    tree_map_pspec(add, spec_tree)
    return total


def run_cell(arch: str, cell_name: str, mesh_kind: str, smoke: bool, out_dir: Path, profile: str = 'baseline'):
    # scoped for the whole lower+compile: the profile travels with this cell,
    # not with process-global state (concurrent cells stay independent)
    with sharding_profile(profile):
        return _run_cell(arch, cell_name, mesh_kind, smoke, out_dir, profile)


def _run_cell(arch: str, cell_name: str, mesh_kind: str, smoke: bool, out_dir: Path, profile: str):
    cfg = C.get(arch, smoke=smoke)
    # smoke: shrink the cells to smoke scale but keep their character
    cell = C.smoke_cell(cell_name) if smoke else C.SHAPES[cell_name]
    mesh = make_mesh(mesh_kind, smoke)
    model = build(cfg)
    rec = {
        "arch": arch, "cell": cell_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh_axis_sizes(mesh)),
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "kind": cell.kind, "ok": False,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
    }
    t0 = time.monotonic()
    try:
        with mesh_context(mesh):
            inputs = model.input_specs(cell)
            in_sh = input_shardings(inputs, mesh)
            if cell.kind == "train":
                opt = make_optimizer(cfg)
                step = TrainStep(model, opt)
                params, opt_state = abstract_state(model, opt)
                specs = model.specs()
                from ..models.common import param_shardings
                p_sh = param_shardings(specs, mesh)
                m_sh = param_shardings(opt.moment_specs(specs), mesh)
                from ..optim import AdamWState
                o_sh = AdamWState(NamedSharding(mesh, PartitionSpec()), m_sh, m_sh)
                jitted = jax.jit(step, in_shardings=(p_sh, o_sh, in_sh),
                                 out_shardings=(p_sh, o_sh, None))
                lowered = jitted.lower(params, opt_state, inputs)
                rec["state_bytes_per_device"] = (
                    analytic_bytes_per_device(specs, mesh)
                    + 2 * analytic_bytes_per_device(opt.moment_specs(specs), mesh)
                )
            elif cell.kind == "prefill":
                from ..models.common import param_shardings
                params = model.abstract()
                p_sh = param_shardings(model.specs(), mesh)
                jitted = jax.jit(model.prefill, in_shardings=(p_sh, in_sh))
                lowered = jitted.lower(params, inputs)
                rec["state_bytes_per_device"] = analytic_bytes_per_device(
                    model.specs(), mesh)
            else:  # decode
                from ..models.common import param_shardings
                params = model.abstract()
                cache = abstract_cache(model, cell)
                p_sh = param_shardings(model.specs(), mesh)
                c_sh = param_shardings(model.cache_specs(cell.global_batch, cell.seq_len), mesh)
                step = DecodeStep(model)
                jitted = jax.jit(step, in_shardings=(p_sh, c_sh, in_sh),
                                 out_shardings=(None, None, c_sh))
                lowered = jitted.lower(params, cache, inputs)
                rec["state_bytes_per_device"] = analytic_bytes_per_device(
                    model.specs(), mesh) + analytic_bytes_per_device(
                    model.cache_specs(cell.global_batch, cell.seq_len), mesh)
            rec["lower_s"] = round(time.monotonic() - t0, 2)
            t1 = time.monotonic()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.monotonic() - t1, 2)

            try:
                ca = compiled_cost_analysis(compiled)
                rec["cost_analysis"] = {
                    k: ca[k] for k in ("flops", "bytes accessed", "transcendentals")
                    if k in ca
                }
            except Exception as e:  # pragma: no cover
                rec["cost_analysis"] = {"error": repr(e)}
            try:
                ma = compiled.memory_analysis()
                rec["memory_analysis"] = {
                    a: int(getattr(ma, a))
                    for a in ("argument_size_in_bytes", "output_size_in_bytes",
                              "temp_size_in_bytes", "alias_size_in_bytes",
                              "generated_code_size_in_bytes")
                    if hasattr(ma, a)
                } or {"repr": repr(ma)}
            except Exception as e:  # pragma: no cover
                rec["memory_analysis"] = {"error": repr(e)}
            try:
                txt = compiled.as_text()
                rec["collectives"] = collective_stats(txt, mesh.devices.size)
            except Exception as e:  # pragma: no cover
                rec["collectives"] = {"error": repr(e)}
            rec["ok"] = True
    except Exception:
        rec["error"] = traceback.format_exc(limit=20)
    rec["total_s"] = round(time.monotonic() - t0, 2)
    out_dir.mkdir(parents=True, exist_ok=True)
    rec["profile"] = profile
    tag = "" if profile == "baseline" else f"__{profile}"
    fn = out_dir / f"{arch}__{cell_name}__{mesh_kind}{tag}.json"
    fn.write_text(json.dumps(rec, indent=1, default=float))
    status = "OK " if rec["ok"] else "FAIL"
    print(f"[{status}] {arch:16s} {cell_name:12s} {mesh_kind:6s} "
          f"lower={rec.get('lower_s', '-'):>7}s compile={rec.get('compile_s', '-'):>7}s",
          flush=True)
    if not rec["ok"]:
        # the traceback must reach the parent process, not just the json
        print(rec["error"], file=sys.stderr, flush=True)
    return rec["ok"]


def driver(args):
    cells = []
    for arch in (args.archs or C.ARCHS):
        cfg = C.get(arch, smoke=args.smoke)
        names = C.cells_for(C.get(arch))  # applicability from the FULL config
        for cell in names:
            for mk in (["single", "multi"] if args.mesh == "both" else [args.mesh]):
                cells.append((arch, cell, mk))
    if args.only_missing:
        cells = [
            (a, c, m) for (a, c, m) in cells
            if not (Path(args.out) / f"{a}__{c}__{m}.json").exists()
            or not json.loads((Path(args.out) / f"{a}__{c}__{m}.json").read_text())["ok"]
        ]
    print(f"dry-run driver: {len(cells)} cells", flush=True)
    fails = []
    for arch, cell, mk in cells:
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--cell", cell, "--mesh", mk, "--out", args.out]
        if args.smoke:
            cmd.append("--smoke")
        cmd += ["--profile", args.profile]
        env = dict(os.environ)
        if args.devices:
            env["REPRO_DRYRUN_DEVICES"] = str(args.devices)
        r = subprocess.run(cmd, env=env)
        if r.returncode != 0:
            fails.append((arch, cell, mk))
    print(f"driver done, {len(fails)} subprocess failures: {fails}", flush=True)
    return 1 if fails else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCHS)
    ap.add_argument("--archs", nargs="*", help="driver: subset of archs")
    ap.add_argument("--cell", choices=list(C.SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both", "moe"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--devices", type=int, default=0, help="driver: fake device count")
    ap.add_argument("--profile", default="baseline", choices=profile_names())
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    if args.all:
        sys.exit(driver(args))
    assert args.arch and args.cell and args.mesh in ("single", "multi", "moe")
    ok = run_cell(args.arch, args.cell, args.mesh, args.smoke, Path(args.out),
                  profile=args.profile)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
