import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# env must precede any jax import (same contract as dryrun.py)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

from repro.launch.roofline import main  # noqa: E402

if __name__ == "__main__":
    main()
