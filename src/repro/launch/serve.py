"""Serving launcher: batched greedy generation with any assigned architecture
(smoke scale on CPU; same engine drives production meshes).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --batch 4

Router mode (--router): a CEFT-routed multi-tenant front-end over a pool of
engines pinned to different sharding profiles; each tick the pending
requests are planned as a task DAG and dispatched along the mapped critical
path (see repro.serve.router).

  PYTHONPATH=src python -m repro.launch.serve --router --tenants 2 \
      --pool serve,baseline --requests 4 --max-new 4
"""
import argparse

import numpy as np

from .. import configs as C
from ..models.common import profile_names
from ..serve import Engine, EngineSlot, Request, Router, ServeConfig


def run_router(args) -> None:
    pool = [p.strip() for p in args.pool.split(",") if p.strip()]
    unknown = [p for p in pool if p not in profile_names()]
    if unknown:
        raise SystemExit(f"unknown pool profile(s) {unknown}; "
                         f"known: {profile_names()}")
    cfg = C.get(args.arch, smoke=True)
    slots = [EngineSlot(f"{args.arch}:{p}#{i}", Engine(cfg, profile=p), p)
             for i, p in enumerate(pool)]
    router = Router(slots, max_batch=args.batch)
    rng = np.random.default_rng(0)
    # tenant i leans to its own prompt-length bucket -> a mixed-class DAG
    tenant_of: dict[int, str] = {}
    for t in range(args.tenants):
        plen = max(2, args.prompt_len >> (t % 2))
        for _ in range(args.requests):
            prompt = rng.integers(2, cfg.vocab, plen).astype(np.int32)
            req = Request(f"tenant{t}", prompt, args.max_new)
            if router.submit(req):
                tenant_of[req.rid] = req.tenant
            else:
                print(f"tenant{t}: request rejected (admission control)")
    done = router.serve()
    print(f"router: {len(done)} requests served on {len(slots)} engines "
          f"({', '.join(s.name for s in slots)})")
    counts: dict[str, int] = {}
    for rid in done:
        counts[tenant_of[rid]] = counts.get(tenant_of[rid], 0) + 1
    for tenant in sorted(counts):
        print(f"router: {tenant}: {counts[tenant]} completed")
    s = router.stats
    print(f"router: plans={s['plans']} (degraded={s['degraded_plans']}) "
          f"cache_hits={s['cache_hits']} partial_sweeps={s['partial_sweeps']} "
          f"invalidations={s['invalidations']} "
          f"dispatches={s['dispatches']} coalesced={s['coalesced']} "
          f"split={s['split']} shed={s['shed']}")
    if router.last_plan is not None:
        path = router.last_plan.path
        print(f"router: last critical path (task, engine): {path} "
              f"cpl={router.last_plan.cpl:.4f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCHS, default="granite-3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--profile", default="serve", choices=profile_names(),
                    help="sharding profile, scoped to this engine")
    ap.add_argument("--router", action="store_true",
                    help="CEFT-routed multi-tenant front-end over a pool")
    ap.add_argument("--tenants", type=int, default=2,
                    help="router mode: number of synthetic tenants")
    ap.add_argument("--requests", type=int, default=4,
                    help="router mode: requests per tenant")
    ap.add_argument("--pool", default="serve,baseline",
                    help="router mode: comma-separated profiles, one engine each")
    args = ap.parse_args()

    if args.router:
        return run_router(args)

    cfg = C.get(args.arch, smoke=True)
    eng = Engine(cfg, profile=args.profile)
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    out = eng.generate(prompts, ServeConfig(max_new_tokens=args.max_new))
    for i, row in enumerate(out):
        print(f"seq {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
