"""Serving launcher: batched greedy generation with any assigned architecture
(smoke scale on CPU; same engine drives production meshes).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --batch 4

Router mode (--router): a CEFT-routed multi-tenant front-end over an elastic
engine pool (repro.serve.pool); each tick the pending requests are planned
as a task DAG and dispatched along the mapped critical path (see
repro.serve.router).  --pool-size replicates the profile list up to N
workers, --backend subprocess puts each worker in its own process with a
measured comm plane, --autoscale lets the pool grow/drain with queue depth.

  PYTHONPATH=src python -m repro.launch.serve --router --tenants 2 \
      --pool serve,baseline --requests 4 --max-new 4
  PYTHONPATH=src python -m repro.launch.serve --router --pool-size 4 \
      --autoscale --backend subprocess --requests 8

Failure containment (--deadline-factor N arms the plan-derived deadline
watchdog; --chaos-seed S additionally runs the whole thing under the
deterministic fault injector and asserts every admitted request completed
exactly once — the local chaos soak):

  PYTHONPATH=src python -m repro.launch.serve --router --pool-size 4 \
      --requests 4 --deadline-factor 3 --chaos-seed 7

SLO plane (--tiers assigns tenants to weighted tiers round-robin; a tier
with an SLO stamps it on every admitted request, and the router propagates
it backward through each tick's plan — see docs/cli.md for the full flag
reference and docs/architecture.md for the request lifecycle):

  PYTHONPATH=src python -m repro.launch.serve --router --tenants 3 \
      --tiers gold:8:2.0,bronze:1 --deadline-factor 3
"""
import argparse
import sys

import numpy as np

from .. import configs as C
from ..core.planners import planner_names
from ..models.common import profile_names
from ..serve import (
    AdmissionQueue,
    Engine,
    EnginePool,
    Request,
    Router,
    ServeConfig,
    TenantTier,
    WorkerSpec,
)


def parse_tiers(spec: str) -> list[TenantTier]:
    """``name:weight[:slo]`` comma-separated, e.g. ``gold:8:2.0,bronze:1``."""
    tiers = []
    for part in [p.strip() for p in spec.split(",") if p.strip()]:
        bits = part.split(":")
        if not 2 <= len(bits) <= 3:
            raise SystemExit(f"--tiers: bad tier {part!r} "
                             "(want name:weight[:slo])")
        try:
            tiers.append(TenantTier(
                bits[0], float(bits[1]),
                float(bits[2]) if len(bits) == 3 else None))
        except ValueError as e:
            raise SystemExit(f"--tiers: {e}")
    return tiers


def run_router(args) -> None:
    profiles = [p.strip() for p in args.pool.split(",") if p.strip()]
    unknown = [p for p in profiles if p not in profile_names()]
    if unknown:
        raise SystemExit(f"unknown pool profile(s) {unknown}; "
                         f"known: {profile_names()}")
    # --pool-size N replicates the profile list round-robin up to N workers
    size = args.pool_size if args.pool_size else len(profiles)
    profiles = [profiles[i % len(profiles)] for i in range(size)]
    cfg = C.get(args.arch, smoke=True)
    if args.backend == "subprocess":
        specs = [WorkerSpec(f"{args.arch}:{p}#{i}", profile=p,
                            factory="repro.serve.pool:smoke_engine_factory",
                            args=(args.arch, p), backend="subprocess")
                 for i, p in enumerate(profiles)]
    else:
        specs = [WorkerSpec(f"{args.arch}:{p}#{i}", profile=p,
                            engine=Engine(cfg, profile=p))
                 for i, p in enumerate(profiles)]
    pool = EnginePool(
        specs,
        probe="measure" if args.backend == "subprocess" else "static",
        autoscale=args.autoscale, max_size=max(size, args.max_pool_size),
        high_water=args.batch)
    if pool.probe != "static":
        pool.refresh_probes()
    chaos = None
    if args.chaos_seed is not None:
        from ..serve.faults import install_chaos
        chaos = install_chaos(pool, args.chaos_seed, rate=args.chaos_rate,
                              hold=1.0)
    deadline_factor = args.deadline_factor if args.deadline_factor > 0 else None
    if chaos is not None and deadline_factor is None:
        deadline_factor = 3.0   # chaos without the watchdog would just hang
    # --tiers: tenant t takes tier t % len(tiers); the queue drains by tier
    # weight and stamps each tier's SLO onto its tenants' requests
    queue = None
    tier_of: dict[str, TenantTier] = {}
    if args.tiers:
        tiers = parse_tiers(args.tiers)
        for t in range(args.tenants):
            tier = tiers[t % len(tiers)]
            tier_of[f"tenant{t}"] = tier
        queue = AdmissionQueue(tiers={
            name: TenantTier(name, tier.weight, tier.slo)
            for name, tier in tier_of.items()})
    # generous floor under chaos or tier SLOs: smoke engines jit-compile on
    # first generate (~1.5s), and a compile must not read as a blown deadline
    # -- with a sub-compile budget floor the watchdog walks every cold worker
    # to strike-3 lost before its first result can land
    slo_tiers = any(t.slo is not None for t in tier_of.values())
    min_deadline = 2.0 if (chaos is not None or slo_tiers) else 0.05
    router = Router(pool, max_batch=args.batch, queue=queue,
                    deadline_factor=deadline_factor, hedge=args.hedge,
                    min_deadline=min_deadline, planner=args.planner,
                    max_split=args.max_split)
    rng = np.random.default_rng(0)
    # tenant i leans to its own prompt-length bucket -> a mixed-class DAG
    tenant_of: dict[int, str] = {}
    for t in range(args.tenants):
        plen = max(2, args.prompt_len >> (t % 2))
        for _ in range(args.requests):
            prompt = rng.integers(2, cfg.vocab, plen).astype(np.int32)
            req = Request(f"tenant{t}", prompt, args.max_new)
            if router.submit(req):
                tenant_of[req.rid] = req.tenant
            else:
                print(f"tenant{t}: request rejected (admission control)")
    try:
        done = router.serve(max_ticks=args.max_ticks)
    finally:
        if chaos is not None:
            chaos.release()
        pool.close()
    names = ", ".join(s.name for s in router.slots)
    print(f"router: {len(done)} requests served on {pool.size} workers "
          f"({names}) backend={args.backend}")
    for name, err in router.failures:
        print(f"router: WORKER LOST {name}: {err}")
    p = pool.stats
    print(f"router: pool launched={p['launched']} lost={p['lost']} "
          f"drained={p['drained']} probes={p['probes']} "
          f"scale_out={p['scale_out']} scale_in={p['scale_in']}")
    counts: dict[str, int] = {}
    for rid in done:
        counts[tenant_of[rid]] = counts.get(tenant_of[rid], 0) + 1
    for tenant in sorted(counts):
        tier = tier_of.get(tenant)
        extra = ("" if tier is None else
                 f" (tier={tier.name} w={tier.weight:g}"
                 + (f" slo={tier.slo:g}s" if tier.slo is not None else "")
                 + ")")
        print(f"router: {tenant}: {counts[tenant]} completed{extra}")
    s = router.stats
    print(f"router: planner={router.planner} max_split={router.max_split} "
          f"split_degree={s['split_degree']} "
          f"moldable_plans={s['moldable_plans']}")
    print(f"router: plans={s['plans']} (degraded={s['degraded_plans']}) "
          f"cache_hits={s['cache_hits']} partial_sweeps={s['partial_sweeps']} "
          f"invalidations={s['invalidations']} "
          f"dispatches={s['dispatches']} coalesced={s['coalesced']} "
          f"split={s['split']} shed={s['shed']}")
    if router.last_plan is not None:
        path = router.last_plan.path
        print(f"router: last critical path (task, engine): {path} "
              f"cpl={router.last_plan.cpl:.4f}s")
    if router.watchdog is not None:
        w = router.watchdog.stats
        print(f"router: watchdog armed={w['armed']} sweeps={w['sweeps']} "
              f"overdue={s['overdue']} overdue_cp={s['overdue_cp']} "
              f"hedges={s['hedges']} stale_replies={s['stale_replies']} "
              f"requeued={s['requeued']} wd_lost={s['watchdog_lost']}")
        print(f"router: slo shed={s['slo_shed']} slo_hedges={s['slo_hedges']} "
              f"clamped_budgets={s['clamped_budgets']}")
    if chaos is not None:
        f = chaos.stats
        fired = {k: v for k, v in f.items() if k != "calls" and v}
        print(f"chaos: seed={args.chaos_seed} calls={f['calls']} "
              f"fired={fired or 'none'}")
        # the soak's contract: every admitted request completes EXACTLY once
        # (zero lost, zero double-completed — duplicates were dropped as
        # stale), and hedge duplicate work stays bounded by the overdue
        # critical-path dispatch count
        admitted = set(tenant_of)
        missing = sorted(admitted - set(done))
        ok = True
        if missing:
            ok = False
            print(f"chaos: FAIL {len(missing)} admitted requests never "
                  f"completed: {missing}")
        if s["completions"] != len(done):
            ok = False
            print(f"chaos: FAIL completion count {s['completions']} != "
                  f"{len(done)} distinct rids (double-completion)")
        if s["hedges"] > s["overdue_cp"]:
            ok = False
            print(f"chaos: FAIL hedges ({s['hedges']}) exceed overdue "
                  f"critical-path dispatches ({s['overdue_cp']})")
        if not ok:
            sys.exit(1)
        print(f"chaos: every admitted request completed exactly once "
              f"({len(done)}/{len(admitted)})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCHS, default="granite-3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--profile", default="serve", choices=profile_names(),
                    help="sharding profile, scoped to this engine")
    ap.add_argument("--router", action="store_true",
                    help="CEFT-routed multi-tenant front-end over a pool")
    ap.add_argument("--planner", default="ceft_cpop",
                    choices=planner_names(include_exhaustive=False),
                    help="router mode: planner from the scheduler registry "
                         "used for every per-tick request-DAG plan")
    ap.add_argument("--max-split", type=int, default=1,
                    help="router mode: moldable prefill ceiling; the planner "
                         "sees each class's prefill as a fork-join of d "
                         "chunks for d in powers of two up to this, and the "
                         "router keeps the degree whose realized schedule "
                         "finishes first (1 = classic prefill->decode chain)")
    ap.add_argument("--tenants", type=int, default=2,
                    help="router mode: number of synthetic tenants")
    ap.add_argument("--requests", type=int, default=4,
                    help="router mode: requests per tenant")
    ap.add_argument("--pool", default="serve,baseline",
                    help="router mode: comma-separated profiles, one engine each")
    ap.add_argument("--pool-size", type=int, default=0,
                    help="router mode: replicate the profile list round-robin "
                         "up to N workers (0 = one per listed profile)")
    ap.add_argument("--backend", choices=("inproc", "subprocess"),
                    default="inproc",
                    help="router mode: worker backend; subprocess workers get "
                         "a measured comm plane (probed transfer rates)")
    ap.add_argument("--autoscale", action="store_true",
                    help="router mode: scale the pool out/in with queue depth")
    ap.add_argument("--max-pool-size", type=int, default=8,
                    help="router mode: autoscale ceiling")
    ap.add_argument("--max-ticks", type=int, default=64,
                    help="router mode: serve-loop tick cap")
    ap.add_argument("--deadline-factor", type=float, default=0.0,
                    help="arm the deadline watchdog: budget = factor x "
                         "planned span per dispatch (0 = disarmed)")
    ap.add_argument("--hedge", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="speculatively re-dispatch overdue critical-path "
                         "work to the degraded plane's best alternate")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="run under the deterministic fault injector with "
                         "this seed and assert exactly-once completion")
    ap.add_argument("--chaos-rate", type=float, default=0.25,
                    help="per-call fault probability for the seeded plan")
    ap.add_argument("--tiers", default="",
                    help="router mode: comma-separated tenant tiers "
                         "name:weight[:slo-seconds], assigned to tenants "
                         "round-robin; weights drive the admission queue's "
                         "weighted drain, SLOs arm backward deadline "
                         "propagation (e.g. gold:8:2.0,bronze:1)")
    args = ap.parse_args()

    if args.router:
        return run_router(args)

    cfg = C.get(args.arch, smoke=True)
    eng = Engine(cfg, profile=args.profile)
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    out = eng.generate(prompts, ServeConfig(max_new_tokens=args.max_new))
    for i, row in enumerate(out):
        print(f"seq {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
