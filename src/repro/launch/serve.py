"""Serving launcher: batched greedy generation with any assigned architecture
(smoke scale on CPU; same engine drives production meshes).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --batch 4
"""
import argparse

import numpy as np

from .. import configs as C
from ..serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCHS, default="granite-3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--profile", default="serve",
                    choices=["baseline", "opt1", "serve", "moe_ep"],
                    help="sharding profile, scoped to this engine")
    args = ap.parse_args()

    cfg = C.get(args.arch, smoke=True)
    eng = Engine(cfg, profile=args.profile)
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    out = eng.generate(prompts, ServeConfig(max_new_tokens=args.max_new))
    for i, row in enumerate(out):
        print(f"seq {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
