"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis (shard_map +
ppermute), for the dense decoder family.

The CEFT partitioner (repro.sched) decides *where* stages go on a
heterogeneous fleet; this module is the *execution* of a contiguous-stage
plan: each pipe-axis device holds layers [i*L/S, (i+1)*L/S); microbatches
stream through with the classic (n_micro + n_stages - 1)-tick schedule.  The
SPMD formulation computes every stage every tick (bubble ticks process
garbage that is masked at the boundaries) -- the standard trade for a single
fused program.

Forward-only here (serving / prefill pipelining); training composes this with
jax.grad through shard_map (ppermute is differentiable) at the cost of
stashing per-tick activations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..models.layers import mlp, rmsnorm, rope_cos_sin
from ..models.transformer import _period_fwd
from ..substrate import shard_map


def _stage_fwd(cfg: ArchConfig, stage_params, x, cos_sin):
    """Apply this device's layers (stacked on axis 0) to x."""
    def body(h, pp):
        h2, _, _ = _period_fwd(cfg, pp, h, cos_sin)
        return h2, None
    out, _ = jax.lax.scan(body, x, stage_params)
    return out


def pipeline_forward(cfg: ArchConfig, blocks, x, mesh, *, n_micro: int,
                     axis: str = "pipe"):
    """blocks: stacked per-layer params (leading dim n_layers, reshaped to
    (n_stages, layers_per_stage, ...)); x: (B, S, D) embedded inputs.
    Returns (B, S, D) hidden states after all layers.

    B must divide into n_micro microbatches.
    """
    n_stages = mesh.shape[axis]
    L = cfg.n_layers // cfg.period
    assert L % n_stages == 0, (L, n_stages)
    B, S, D = x.shape
    assert B % n_micro == 0
    mb = B // n_micro
    ticks = n_micro + n_stages - 1

    staged = jax.tree.map(
        lambda a: a.reshape((n_stages, L // n_stages) + a.shape[1:]), blocks)
    xm = x.reshape(n_micro, mb, S, D)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (mb, S))
    cos_sin = rope_cos_sin(cfg, positions) if cfg.use_rope and cfg.n_heads else None

    def per_stage(stage_params, xm_local):
        sid = jax.lax.axis_index(axis)

        def tick(carry, t):
            buf = carry                           # activation received last tick
            m = jnp.clip(t - sid, 0, n_micro - 1)
            inp0 = jax.lax.dynamic_index_in_dim(xm_local, m, 0, keepdims=False)
            inp = jnp.where(sid == 0, inp0, buf)
            out = _stage_fwd(cfg, stage_params, inp, cos_sin)
            # pass to the next stage (ring; last->first carries garbage)
            nxt = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return nxt, out

        _, outs = jax.lax.scan(tick, jnp.zeros((mb, S, D), x.dtype),
                               jnp.arange(ticks))
        # keep only the last stage's valid ticks: tick t emits microbatch
        # t - (n_stages-1); zero elsewhere so a psum over the axis selects it
        valid = (jnp.arange(ticks) >= n_stages - 1)[:, None, None, None]
        is_last = (sid == n_stages - 1)
        contrib = jnp.where(valid & is_last, outs, 0.0)
        contrib = contrib[n_stages - 1:]          # (n_micro, mb, S, D)
        return jax.lax.psum(contrib, axis)

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),   # stage params sharded; inputs replicated
        out_specs=P(),
    )
    out = fn(staged, xm)
    return out.reshape(B, S, D)
