"""repro.launch — mesh, step builders, multi-pod dry-run, roofline."""
from .mesh import make_production_mesh, make_test_mesh, mesh_axis_sizes
from .steps import build_decode, build_prefill, build_train

__all__ = ["build_decode", "build_prefill", "build_train",
           "make_production_mesh", "make_test_mesh", "mesh_axis_sizes"]
