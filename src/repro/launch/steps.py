"""Step builders: jit-ready train / prefill / decode functions with the full
sharding contract (params, optimizer state, inputs, caches)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs.base import ArchConfig, ShapeCell
from ..models.common import (
    abstract_params,
    init_params,
    param_shardings,
    resolve_spec,
    tree_map_pspec,
)
from ..models.model import Model
from ..optim import AdamW, for_config
from .mesh import mesh_axis_sizes

# logical axes of every named model input
INPUT_LOGICAL: dict[str, tuple[str, ...]] = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "embeds": ("batch", "seq", "none"),
    "positions": ("none", "batch", "seq"),
    "frames": ("batch", "none", "none"),
    "pos": (),
}


def input_shardings(inputs: dict[str, jax.ShapeDtypeStruct], mesh):
    ms = mesh_axis_sizes(mesh)
    out = {}
    for k, v in inputs.items():
        logical = INPUT_LOGICAL[k]
        out[k] = NamedSharding(mesh, resolve_spec(v.shape, logical, ms))
    return out


def make_optimizer(cfg: ArchConfig, total_steps: int = 10_000,
                   peak_lr: float = 3e-4) -> AdamW:
    lr = for_config(cfg.schedule, peak=peak_lr, warmup=min(500, total_steps // 10),
                    total=total_steps)
    return AdamW(lr=lr, moment_dtype=cfg.optstate_dtype)


@dataclasses.dataclass(frozen=True)
class TrainStep:
    model: Model
    optimizer: AdamW

    def __call__(self, params, opt_state, batch):
        loss, grads = jax.value_and_grad(self.model.loss)(params, batch)
        new_p, new_s, gnorm = self.optimizer.update(grads, opt_state, params)
        return new_p, new_s, {"loss": loss, "grad_norm": gnorm}


def build_train(model: Model, mesh, total_steps: int = 10_000,
                peak_lr: float = 3e-4):
    """Returns (jitted step, abstract (params, opt_state), shardings dict)."""
    opt = make_optimizer(model.cfg, total_steps, peak_lr)
    step = TrainStep(model, opt)
    specs = model.specs()
    p_sh = param_shardings(specs, mesh)
    m_sh = param_shardings(opt.moment_specs(specs), mesh)
    from ..optim import AdamWState
    o_sh = AdamWState(NamedSharding(mesh, PartitionSpec()), m_sh, m_sh)
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )
    return jitted, opt, {"params": p_sh, "opt": o_sh}


def build_prefill(model: Model, mesh):
    specs = model.specs()
    p_sh = param_shardings(specs, mesh)
    jitted = jax.jit(model.prefill, in_shardings=(p_sh, None))
    return jitted, {"params": p_sh}


@dataclasses.dataclass(frozen=True)
class DecodeStep:
    model: Model

    def __call__(self, params, cache, inputs: dict):
        logits, new_cache = self.model.decode(
            params, cache, inputs["tokens"], inputs["pos"],
            positions=inputs.get("positions"),
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache


def build_decode(model: Model, mesh, cell: ShapeCell):
    step = DecodeStep(model)
    specs = model.specs()
    p_sh = param_shardings(specs, mesh)
    c_specs = model.cache_specs(cell.global_batch, cell.seq_len)
    c_sh = param_shardings(c_specs, mesh)
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, c_sh, None),
        out_shardings=(None, None, c_sh),
        donate_argnums=(1,),
    )
    return jitted, {"params": p_sh, "cache": c_sh}


def abstract_state(model: Model, opt: AdamW):
    """Abstract (params, opt_state) for dry-run lowering."""
    specs = model.specs()
    params = abstract_params(specs, jnp.dtype(model.cfg.param_dtype))
    mspec = opt.moment_specs(specs)
    m = abstract_params(mspec, jnp.dtype(opt.moment_dtype))
    v = abstract_params(mspec, jnp.dtype(opt.moment_dtype))
    count = jax.ShapeDtypeStruct((), jnp.int32)
    from ..optim import AdamWState
    return params, AdamWState(count, m, v)


def abstract_cache(model: Model, cell: ShapeCell):
    return tree_map_pspec(
        lambda _, p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype)),
        model.cache_specs(cell.global_batch, cell.seq_len),
    )
