"""Roofline analysis via component probes (deliverable g).

XLA's cost_analysis does NOT scale ``scan`` bodies by trip count (verified
empirically -- a scan of 10 matmuls reports 1 matmul of flops), so whole-model
numbers from the dry-run compile are per-iteration only.  Instead we compile
every *scan-free component* of the step on the production mesh (same sharding
constraints as the model), read its per-device HLO flops / bytes / collective
bytes, and assemble the cell's totals with exact trip counts taken from the
code structure:

    layer scans        x n_layers (per kind)
    attention tiles    x nq * nk  (the online-softmax chunk grid; the masked
                                   upper triangle is counted -- that waste is
                                   real in our implementation and is visible in
                                   the useful-FLOPs ratio)
    SSD chunks         x S / Q
    loss chunks        x S / loss_chunk
    optimizer update   x param_bytes / probe_bytes

Training components are compiled as jax.value_and_grad (fwd+bwd in one
program); remat="full" adds one extra forward per layer, exactly like the
jax.checkpoint policy in the model.

Terms (per device == global/(chips x peak), cost_analysis is per-device under
SPMD -- verified):

    compute_s    = flops / peak_flops          (197 TF/s bf16, v5e)
    memory_s     = bytes / hbm_bw              (819 GB/s)
    collective_s = collective_bytes / link_bw  (50 GB/s/link ICI)
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..configs.base import ArchConfig, ShapeCell
from ..models import common as mc
from ..models.layers import (
    attn_decode,
    attn_specs,
    mlp,
    mlp_specs,
    qkv_proj,
    rmsnorm,
    rmsnorm_spec,
)
from ..models.moe import moe, moe_specs
from ..models.ssm import ssd_decode, ssd_prefill, ssm_specs
from ..models.common import (
    PSpec,
    ShardingProfile,
    abstract_params,
    active_profile,
    param_shardings,
    profile_names,
    resolve_profile,
    resolve_spec,
    sharding_profile,
)
from ..substrate import compiled_cost_analysis, mesh_context
from .hlo_stats import collective_stats
from .mesh import mesh_axis_sizes

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}
Q_CHUNK, K_CHUNK = 512, 1024  # layers.chunked_attention defaults


def _sh(mesh, shape, logical):
    return NamedSharding(mesh, resolve_spec(shape, logical, mesh_axis_sizes(mesh)))


def _io_bytes_per_device(args, shardings, out_avals, mesh) -> float:
    """Fusion-ideal HBM traffic: every input read once, every output written
    once, at the per-device shard sizes (the TPU roofline convention; the
    XLA:CPU 'bytes accessed' has no fusion and overcounts intermediates)."""
    total = 0.0
    for a, sh in zip(jax.tree.leaves(args), jax.tree.leaves(shardings)):
        shp = sh.shard_shape(a.shape) if hasattr(sh, "shard_shape") else a.shape
        total += float(np.prod(shp)) * jnp.dtype(a.dtype).itemsize
    ms = mesh_axis_sizes(mesh)
    n = float(np.prod(list(ms.values())))
    for o in jax.tree.leaves(out_avals):
        # outputs: assume they shard as well as the batch-heaviest input (XLA
        # picks); divide by the full device count as the optimistic bound
        total += float(np.prod(o.shape)) * jnp.dtype(o.dtype).itemsize / n
    return total


def _compile_stats(fn, args, shardings, mesh) -> dict:
    with mesh_context(mesh):
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        compiled = lowered.compile()
    ca = compiled_cost_analysis(compiled)
    coll = collective_stats(compiled.as_text(), mesh.devices.size)
    out_avals = jax.eval_shape(fn, *args)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_hlo": float(ca.get("bytes accessed", 0.0)),
        "bytes": _io_bytes_per_device(args, shardings, out_avals, mesh),
        "coll": float(coll["collective_bytes_per_device"]),
    }


@dataclasses.dataclass
class Probe:
    name: str
    fn: Callable
    args: tuple
    shardings: tuple
    trips: float
    grad: bool = False  # compile value_and_grad instead of fn


def _scalarize(fn):
    def wrapped(*args):
        out = fn(*args)
        leaves = jax.tree.leaves(out)
        return sum(jnp.sum(x.astype(jnp.float32)) for x in leaves)
    return wrapped


def _abs(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_probes(cfg: ArchConfig, cell: ShapeCell, mesh) -> list[Probe]:
    B, S = cell.global_batch, cell.seq_len
    D = cfg.d_model
    bf16 = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    train = cell.kind == "train"
    decode = cell.kind == "decode"
    probes: list[Probe] = []
    pattern = cfg.layer_pattern()
    reps = cfg.n_layers // cfg.period
    n_attn = sum(1 for mx, _ in pattern if mx == "attn") * reps
    n_ssm = sum(1 for mx, _ in pattern if mx == "ssm") * reps
    n_mlp = sum(1 for _, ch in pattern if ch == "mlp") * reps
    n_moe = sum(1 for _, ch in pattern if ch == "moe") * reps
    if cfg.family == "encdec":
        # self+cross projections at S tokens; encoder blocks at enc_seq tokens
        # are folded in as fractional trips of the S-token probes
        frac = cfg.enc_seq / max(S, 1)
        n_attn = cfg.n_layers * 2 + cfg.enc_layers * frac
        n_mlp = cfg.n_layers + cfg.enc_layers * frac

    x_sh = _sh(mesh, (B, S, D), ("batch", "seq", "none"))
    x_abs = _abs((B, S, D), bf16)

    def add(name, fn, params_specs, extra_args, extra_sh, trips, grad,
            argnums=(0, 1)):
        p_abs = abstract_params(params_specs, jnp.float32)
        p_sh = param_shardings(params_specs, mesh)
        f = _scalarize(fn) if grad else fn
        g = jax.value_and_grad(f, argnums=argnums) if grad else fn
        probes.append(Probe(name, g, (p_abs,) + extra_args, (p_sh,) + extra_sh,
                            trips, grad))

    # ---------------------------------------------------------- attention
    if n_attn and not decode:
        specs = {"norm": rmsnorm_spec(D), **attn_specs(cfg)}

        def attn_proj(p, x):
            h = rmsnorm(p["norm"], x, cfg.norm_eps)
            q, k, v = qkv_proj(p, h, cfg, None)
            Bx, Sx = x.shape[:2]
            ctx = jnp.repeat(v, cfg.n_heads // cfg.n_kv_heads, axis=2)
            out = ctx.reshape(Bx, Sx, -1) @ p["wo"].astype(x.dtype)
            return x + out

        add("attn_proj", attn_proj, specs, (x_abs,), (x_sh,), n_attn, train)

        hq, hd = cfg.n_heads, cfg.hd
        # flat-Hq layout: the model constrains q as (B,S,Hq,hd) with Hq on the
        # model axis (divisible for every assigned arch); k/v arrive expanded
        # across GQA groups, as XLA materializes them inside the scan
        qt = _abs((B, hq, Q_CHUNK, hd), bf16)
        kt = _abs((B, hq, hd, K_CHUNK), bf16)
        vt = _abs((B, hq, K_CHUNK, hd), bf16)
        st_m = _abs((B, hq, Q_CHUNK), jnp.float32)
        st_acc = _abs((B, hq, Q_CHUNK, hd), jnp.float32)
        # heads take the model axis when divisible; otherwise the q-chunk
        # dim does (matching XLA's behavior of keeping seq sharding and
        # all-gathering k/v when the head count does not divide)
        tile_sh = (
            _sh(mesh, qt.shape, ("batch", "heads", "tile_q", "none")),
            _sh(mesh, kt.shape, ("batch", "heads", "none", "none")),
            _sh(mesh, vt.shape, ("batch", "heads", "none", "none")),
            _sh(mesh, st_m.shape, ("batch", "heads", "tile_q")),
            _sh(mesh, st_m.shape, ("batch", "heads", "tile_q")),
            _sh(mesh, st_acc.shape, ("batch", "heads", "tile_q", "none")),
        )

        def attn_tile(q, kT, vT, m_run, l_run, acc):
            scale = 1.0 / math.sqrt(hd)
            s = (jnp.einsum("bhqd,bhdk->bhqk", q, kT) * scale).astype(jnp.float32)
            m2 = jnp.maximum(m_run, s.max(axis=-1))
            alpha = jnp.exp(m_run - m2)
            pexp = jnp.exp(s - m2[..., None])
            l2 = l_run * alpha + pexp.sum(axis=-1)
            acc2 = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", pexp.astype(vT.dtype), vT).astype(jnp.float32)
            return m2, l2, acc2

        nq = max(1, math.ceil(S / Q_CHUNK))
        nk = max(1, math.ceil(S / K_CHUNK))
        if cfg.family == "encdec":  # enc (TxT) + dec self (SxS) + cross (SxT)
            T = cfg.enc_seq
            tiles = (cfg.enc_layers * math.ceil(T / Q_CHUNK) * math.ceil(T / K_CHUNK)
                     + cfg.n_layers * nq * nk
                     + cfg.n_layers * nq * math.ceil(T / K_CHUNK))
        else:
            tiles = n_attn * nq * nk
        probes.append(Probe(
            "attn_tile",
            (jax.value_and_grad(_scalarize(attn_tile), argnums=(0, 1, 2))
             if train else attn_tile),
            (qt, kt, vt, st_m, st_m, st_acc), tile_sh, tiles, train))

    if n_attn and decode:
        specs = {"norm": rmsnorm_spec(D), **attn_specs(cfg)}
        Sc = min(S, cfg.window) if cfg.window else S
        cache_abs = {"k": _abs((B, Sc, cfg.n_kv_heads, cfg.hd), bf16),
                     "v": _abs((B, Sc, cfg.n_kv_heads, cfg.hd), bf16)}
        cache_sh = {k: _sh(mesh, v.shape, ("cache_batch", "cache_seq", "heads", "cache_hd"))
                    for k, v in cache_abs.items()}
        x1 = _abs((B, 1, D), bf16)
        x1_sh = _sh(mesh, x1.shape, ("batch", "none", "none"))

        def dec_attn(p, x, cache, pos):
            h = rmsnorm(p["norm"], x, cfg.norm_eps)
            out, nc = attn_decode(p, h, cfg, cache, pos, None, window=cfg.window)
            return x + out, nc

        add("dec_attn", dec_attn, specs,
            (x1, cache_abs, _abs((), jnp.int32)),
            (x1_sh, cache_sh, NamedSharding(mesh, PartitionSpec())),
            n_attn, False)

    # ---------------------------------------------------------------- ssd
    if n_ssm:
        specs = {"block_norm": rmsnorm_spec(D), "ssm": ssm_specs(cfg)}
        if decode:
            di, H, P, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            x1 = _abs((B, 1, D), bf16)
            st = {"ssm": _abs((B, H, P, N), jnp.float32),
                  "conv": _abs((B, cfg.ssm_conv - 1, di + 2 * N), bf16)}
            st_sh = {"ssm": _sh(mesh, st["ssm"].shape, ("cache_batch", "ssm_inner", "none", "none")),
                     "conv": _sh(mesh, st["conv"].shape, ("cache_batch", "none", "ssm_inner"))}

            def dec_ssd(p, x, state):
                h = rmsnorm(p["block_norm"], x, cfg.norm_eps)
                out, ns = ssd_decode(p["ssm"], h, cfg, state)
                return x + out, ns

            add("dec_ssd", dec_ssd, specs, (x1, st),
                (_sh(mesh, x1.shape, ("batch", "none", "none")), st_sh),
                n_ssm, False)
        else:
            # (a) per-layer projections: weights stream from HBM once per layer
            di, H, P, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

            def ssm_proj(p, x):
                from ..models.ssm import _causal_conv
                h = rmsnorm(p["block_norm"], x, cfg.norm_eps)
                zxbcdt = h @ p["ssm"]["in_proj"].astype(h.dtype)
                z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
                xbc = _causal_conv(xbc, p["ssm"]["conv_w"].astype(h.dtype),
                                   p["ssm"]["conv_b"].astype(h.dtype))
                xs = xbc[..., :di]
                y = rmsnorm(p["ssm"]["norm"], xs * jax.nn.silu(z), cfg.norm_eps)
                return x + y @ p["ssm"]["out_proj"].astype(h.dtype)

            add("ssm_proj", ssm_proj, specs, (x_abs,), (x_sh,), n_ssm, train)

            # (b) per-chunk inner SSD (dual form + state construction), no
            # weights -- mirrors ssm.ssd_prefill's chunk math exactly
            Q = cfg.ssm_chunk
            xh = _abs((B, Q, H, P), bf16)
            Bh = _abs((B, Q, N), jnp.float32)
            dth = _abs((B, Q, H), jnp.float32)
            inner_sh = (
                _sh(mesh, xh.shape, ("batch", "none", "ssm_inner", "none")),
                _sh(mesh, Bh.shape, ("batch", "none", "none")),
                _sh(mesh, Bh.shape, ("batch", "none", "none")),
                _sh(mesh, dth.shape, ("batch", "none", "ssm_inner")),
            )

            def ssd_inner(xh, Bc, Cc, dt):
                from ..models.ssm import _segsum
                A = -jnp.ones((H,), jnp.float32) * 0.5
                dA = dt * A
                dAc = jnp.cumsum(dA, axis=1)
                L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, 1)))
                scores = jnp.einsum("bin,bjn->bij", Cc, Bc)
                M = scores[:, None] * L
                xdt = xh * dt[..., None].astype(xh.dtype)
                y_diag = jnp.einsum("bhij,bjhp->bihp", M.astype(xh.dtype), xdt)
                decay = jnp.exp(dAc[:, -1:, :] - dAc)
                states = jnp.einsum("bqn,bqh,bqhp->bhpn", Bc,
                                    (dt * decay), xh.astype(jnp.float32))
                y_off = jnp.einsum("bqn,bhpn,bqh->bqhp", Cc, states,
                                   jnp.exp(dAc)).astype(xh.dtype)
                return y_diag + y_off

            probes.append(Probe(
                "ssd_inner",
                (jax.value_and_grad(_scalarize(ssd_inner), argnums=(0, 1, 2, 3))
                 if train else ssd_inner),
                (xh, Bh, Bh, dth), inner_sh,
                n_ssm * math.ceil(S / Q), train))

    # ------------------------------------------------------------- mlp/moe
    tok_shape = (B, 1, D) if decode else (B, S, D)
    tok_abs = _abs(tok_shape, bf16)
    tok_sh = _sh(mesh, tok_shape, ("batch", "seq" if not decode else "none", "none"))
    if n_mlp:
        specs = {"norm": rmsnorm_spec(D), **mlp_specs(cfg)}

        def mlp_block(p, x):
            return x + mlp(p, rmsnorm(p["norm"], x, cfg.norm_eps), cfg)

        add("mlp_block", mlp_block, specs, (tok_abs,), (tok_sh,), n_mlp, train)
    if n_moe:
        specs = {"norm": rmsnorm_spec(D), **moe_specs(cfg)}

        def moe_block(p, x):
            y, aux = moe(p, rmsnorm(p["norm"], x, cfg.norm_eps), cfg)
            return x + y + aux

        add("moe_block", moe_block, specs, (tok_abs,), (tok_sh,), n_moe, train)

    # ------------------------------------------------------- embed + loss
    emb_spec = {"embed": PSpec((cfg.vocab, D), ("vocab", "embed_d"), init="embed")}
    if decode:
        tok = _abs((B, 1), jnp.int32)

        def emb_unemb(p, t):
            x = p["embed"][t].astype(bf16)
            return (x @ p["embed"].T.astype(bf16)).astype(jnp.float32)

        add("embed+unembed", emb_unemb, emb_spec,
            (tok,), (_sh(mesh, tok.shape, ("batch", "none")),), 1, False)
    else:
        c = min(cfg.loss_chunk, S)
        spec = {"unembed": PSpec((D, cfg.vocab), ("embed_d", "vocab"))}
        hc = _abs((B, c, D), bf16)
        lc = _abs((B, c), jnp.int32)

        def loss_chunk(p, h, l):
            logits = (h @ p["unembed"].astype(h.dtype)).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
            return jnp.sum(lse - gold)

        add("loss_chunk", loss_chunk, spec,
            (hc, lc), (_sh(mesh, hc.shape, ("batch", "none", "none")),
                       _sh(mesh, lc.shape, ("batch", "none"))),
            math.ceil(S / c), train)

        tok = _abs((B, S), jnp.int32)

        def emb(p, t):
            return p["embed"][t].astype(bf16)

        add("embed", emb, emb_spec, (tok,),
            (_sh(mesh, tok.shape, ("batch", "seq")),), 1, train, argnums=(0,))

    # ------------------------------------------------------------ optimizer
    if train:
        probe_shape = (4096, 4096)
        pb = _abs(probe_shape, jnp.float32)
        mb = _abs(probe_shape, jnp.dtype(cfg.optstate_dtype))
        psh = _sh(mesh, probe_shape, ("embed", "ffn"))

        def adam_probe(p, g, m1, v1):
            m2 = 0.9 * m1.astype(jnp.float32) + 0.1 * g
            v2 = 0.95 * v1.astype(jnp.float32) + 0.05 * g * g
            step = m2 / (jnp.sqrt(v2) + 1e-8) + 0.1 * p
            return (p - 1e-3 * step,
                    m2.astype(m1.dtype), v2.astype(v1.dtype))

        trips = cfg.n_params() / float(np.prod(probe_shape))
        probes.append(Probe("adamw", adam_probe, (pb, pb, mb, mb),
                            (psh, psh, psh, psh), trips, False))
    return probes


def analyze_cell(cfg: ArchConfig, cell: ShapeCell, mesh,
                 profile: str | ShardingProfile | None = None) -> dict:
    # all probe construction + lowering happens under one scoped profile, so
    # concurrent analyses with different profiles cannot race
    prof = resolve_profile(profile) if profile is not None else active_profile()
    with sharding_profile(prof):
        return _analyze_cell(cfg, cell, mesh, prof)


def _analyze_cell(cfg: ArchConfig, cell: ShapeCell, mesh,
                  prof: ShardingProfile) -> dict:
    chips = int(mesh.devices.size)
    comps = {}
    totals = {"flops": 0.0, "bytes": 0.0, "bytes_hlo": 0.0, "coll": 0.0}
    for pr in build_probes(cfg, cell, mesh):
        st = _compile_stats(pr.fn, pr.args, pr.shardings, mesh)
        comps[pr.name] = {**st, "trips": pr.trips, "grad": pr.grad}
        for k in totals:
            totals[k] += st[k] * pr.trips
        # remat="full": backward recomputes the forward once more
        if pr.grad and cfg.remat == "full" and pr.name != "loss_chunk":
            # approximation: fwd ~ (vag - fwd) ~ vag/3 for matmul-bound blocks
            totals["flops"] += st["flops"] / 3.0 * pr.trips
            totals["bytes"] += st["bytes"] / 3.0 * pr.trips

    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    n = cfg.n_active_params()
    model_flops = (6.0 if cell.kind == "train" else 2.0) * n * tokens
    hlo_global = totals["flops"] * chips
    terms = {
        "compute_s": totals["flops"] / HW["peak_flops"],
        "memory_s": totals["bytes"] / HW["hbm_bw"],
        "collective_s": totals["coll"] / HW["link_bw"],
    }
    terms_upper = {"memory_hlo_s": totals["bytes_hlo"] / HW["hbm_bw"]}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "arch": cfg.name, "cell": cell.name, "chips": chips,
        "profile": prof.name,
        "mesh_shape": dict(mesh_axis_sizes(mesh)),
        "terms": terms, "terms_upper": terms_upper, "dominant": dominant,
        "step_time_lower_bound_s": bound,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": model_flops / max(hlo_global, 1.0),
        "roofline_fraction": (model_flops / HW["peak_flops"] / chips) / max(bound, 1e-30),
        "components": comps,
    }


def main():
    import argparse
    from .. import configs as C
    from .dryrun import make_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCHS, required=False)
    ap.add_argument("--cell", choices=list(C.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "moe"])
    ap.add_argument("--smoke", action="store_true",
                    help="small fake fleet, smoke configs + shrunk cells")
    ap.add_argument("--profile", default="baseline", choices=profile_names())
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()
    mesh = make_mesh(args.mesh, smoke=args.smoke)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    cells = ([(args.arch, args.cell)] if not args.all else
             [(a, c) for a in C.ARCHS for c in C.cells_for(C.get(a))])
    for arch, cell_name in cells:
        cfg = C.get(arch, smoke=args.smoke)
        cell = C.smoke_cell(cell_name) if args.smoke else C.SHAPES[cell_name]
        try:
            rec = analyze_cell(cfg, cell, mesh, profile=args.profile)
        except Exception as e:  # pragma: no cover
            import traceback
            rec = {"arch": arch, "cell": cell_name, "error": traceback.format_exc(limit=15)}
        rec["profile"] = args.profile
        tag = "" if args.profile == "baseline" else f"__{args.profile}"
        (out / f"{arch}__{cell_name}__{args.mesh}{tag}.json").write_text(
            json.dumps(rec, indent=1, default=float))
        if "terms" in rec:
            t = rec["terms"]
            print(f"{arch:16s} {cell_name:12s} comp={t['compute_s']*1e3:9.3f}ms "
                  f"mem={t['memory_s']*1e3:9.3f}ms coll={t['collective_s']*1e3:9.3f}ms "
                  f"dom={rec['dominant'][:-2]:10s} useful={rec['useful_flops_ratio']:.2f} "
                  f"roofline={rec['roofline_fraction']:.2f}", flush=True)
        else:
            print(f"{arch:16s} {cell_name:12s} ERROR", flush=True)


if __name__ == "__main__":
    main()
