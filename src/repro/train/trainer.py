"""Fault-tolerant training loop.

Production behaviors, all exercised by tests at smoke scale:
  * checkpoint every N steps (atomic, checksummed, optionally async)
  * supervisor loop: a step failure (simulated node loss via FailureInjector,
    or any exception) triggers mesh re-formation and restore from the newest
    *valid* checkpoint -- corrupt checkpoints are skipped automatically
  * elastic re-shard: restore accepts a different mesh (data axis grown or
    shrunk); params are re-laid-out from host shards via per-leaf shardings
  * straggler mitigation: per-step wall times feed the EWMA monitor; a tripped
    threshold re-plans the layer-DAG schedule with CEFT-CPOP (repro.sched)
  * deterministic data: batch i is a pure function of (seed, i) -- restart
    replays the identical stream
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable

import jax
import numpy as np

from .. import checkpoint as ckpt_lib
from ..configs.base import ArchConfig, ShapeCell
from ..data.pipeline import DataConfig, SyntheticLM
from ..models.common import (
    init_params,
    param_shardings,
    resolve_profile,
    sharding_profile,
)
from ..models.model import Model, build
from ..substrate import mesh_context
from ..launch.steps import build_train, input_shardings, make_optimizer
from ..sched.layer_dag import build_layer_dag
from ..sched.straggler import StragglerMonitor


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 50
    ckpt_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = False
    seed: int = 0
    fail_at_steps: tuple[int, ...] = ()    # simulated node failures
    max_restarts: int = 3
    straggler_sim: dict | None = None       # {step: (class, slowdown)} simulation
    log_every: int = 10
    peak_lr: float = 5e-3                   # smoke-scale default
    profile: str = "baseline"               # sharding profile, scoped per-trainer


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg: ArchConfig, cell: ShapeCell, tcfg: TrainerConfig,
                 mesh_factory: Callable[[], "jax.sharding.Mesh"]):
        self.cfg = cfg
        self.cell = cell
        self.tcfg = tcfg
        # pinned once; every trace/execution below re-enters it, so a trainer
        # and a serve engine (or two trainers) never race on profile state
        self.profile = resolve_profile(tcfg.profile)
        self.mesh_factory = mesh_factory
        self.model = build(cfg)
        self.data = SyntheticLM(DataConfig(cfg.vocab, cell.seq_len,
                                           cell.global_batch, tcfg.seed))
        self.metrics: list[dict] = []
        self.restarts = 0
        g, comp, m, labels = build_layer_dag(cfg, cell)
        self._sched_inputs = (g, comp, m)
        self.monitor = StragglerMonitor(m.P)
        self._setup()

    # ------------------------------------------------------------------ setup
    def _setup(self):
        self._warmup_steps = 1  # first step after (re)setup includes jit compile
        self.mesh = self.mesh_factory()
        with sharding_profile(self.profile), mesh_context(self.mesh):
            self.step_fn, self.opt, sh = build_train(
                self.model, self.mesh, total_steps=self.tcfg.steps,
                peak_lr=self.tcfg.peak_lr)
            self.shardings = sh
            self.in_sh = input_shardings(
                self.model.input_specs(self.cell), self.mesh)

    def _fresh_state(self):
        with sharding_profile(self.profile), mesh_context(self.mesh):
            params = jax.jit(
                self.model.init, out_shardings=self.shardings["params"]
            )(jax.random.PRNGKey(self.tcfg.seed))
            # moments must land on their declared (FSDP) shardings, not the
            # default replicated layout -- jit with explicit out_shardings
            opt_state = jax.jit(
                self.opt.init, out_shardings=self.shardings["opt"]
            )(params)
        return params, opt_state

    # ------------------------------------------------------------- checkpoint
    def _save(self, step, params, opt_state):
        tree = {"params": params, "opt": opt_state}
        ckpt_lib.save(self.tcfg.ckpt_dir, step, tree, async_=self.tcfg.ckpt_async)

    def _restore_latest(self, params_like, opt_like):
        step = ckpt_lib.latest_valid(self.tcfg.ckpt_dir)
        if step is None:
            return 0, None
        sh = {"params": self.shardings["params"], "opt": self.shardings["opt"]}
        tree = ckpt_lib.restore(self.tcfg.ckpt_dir, step,
                                {"params": params_like, "opt": opt_like}, sh)
        return step + 1, tree

    # -------------------------------------------------------------------- run
    def run(self) -> list[dict]:
        params, opt_state = self._fresh_state()
        start = 0
        self._save(0, params, opt_state)  # step-0 anchor for recovery
        step = 1
        while step <= self.tcfg.steps:
            try:
                t0 = time.monotonic()
                if step in self.tcfg.fail_at_steps and self.restarts < len(self.tcfg.fail_at_steps):
                    self.restarts += 1
                    raise SimulatedFailure(f"node lost at step {step}")
                batch = self.data.sharded_batch(step - 1, self.in_sh)
                with sharding_profile(self.profile), mesh_context(self.mesh):
                    params, opt_state, m = self.step_fn(params, opt_state, batch)
                loss = float(m["loss"])
                dt = time.monotonic() - t0
                self._observe_stragglers(step, dt)
                if step % self.tcfg.log_every == 0 or step == self.tcfg.steps:
                    self.metrics.append({"step": step, "loss": loss,
                                         "grad_norm": float(m["grad_norm"]),
                                         "time_s": dt})
                if step % self.tcfg.ckpt_every == 0:
                    self._save(step, params, opt_state)
                step += 1
            except SimulatedFailure as e:
                if self.restarts > self.tcfg.max_restarts:
                    raise
                self.metrics.append({"step": step, "event": f"restart: {e}"})
                self._setup()  # re-form mesh from survivors
                p_like, o_like = self._fresh_state()
                start, tree = self._restore_latest(p_like, o_like)
                if tree is not None:
                    params, opt_state = tree["params"], tree["opt"]
                    step = start
                else:
                    params, opt_state = p_like, o_like
                    step = 1
        self._save(self.tcfg.steps, params, opt_state)
        return self.metrics

    # -------------------------------------------------------------- straggler
    def _observe_stragglers(self, step: int, dt: float):
        if self._warmup_steps > 0:  # compile-time contaminated measurement
            self._warmup_steps -= 1
            return
        g, comp, m = self._sched_inputs
        sim = (self.tcfg.straggler_sim or {}).get(step)
        # simulation mode uses a synthetic unit base so the injected slowdown
        # is not masked by wall-clock noise; live mode uses measured times
        base = 1.0 if self.tcfg.straggler_sim is not None else dt
        times = np.ones(m.P) * base
        if sim is not None:
            cls, slow = sim
            times[cls] *= slow
        sched, ev = self.monitor.maybe_replan(step, g, comp, m, times)
        if ev is not None:
            self.metrics.append({
                "step": step, "event": "straggler_replan",
                "class": ev.device_class, "slowdown": round(ev.slowdown, 2),
                "makespan_ratio": round(ev.new_makespan / ev.old_makespan, 3),
            })
