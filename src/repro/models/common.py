"""Model substrate plumbing: spec-first parameters and logical-axis sharding.

Spec-first parameters: model builders return a *tree of PSpec* (shape + logical
axis names + init kind).  The tree is materialized three ways:
  * ``init_params``      -> real arrays (training / smoke tests)
  * ``abstract_params``  -> ShapeDtypeStruct (the multi-pod dry-run: no bytes)
  * ``param_shardings``  -> NamedSharding per leaf from the logical rules

Logical-axis sharding with divisibility degradation (DESIGN.md §5): a logical
axis maps to mesh axes only when the dimension is divisible by their product,
so minicpm's 36 heads stay replicated on a 16-way model axis while llama's 128
heads shard -- one rules table serves all ten architectures.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..substrate import constrain_spec, current_axis_sizes, degrade_spec

# logical axis name -> preferred mesh axes (applied greedily, outermost first)
LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),        # sequence-parallel residual stream (train/prefill)
    "cache_seq": ("model",),  # decode-SP: KV cache sharded along sequence
    "cache_hd": (),           # alternative: cache head_dim sharding
    "cache_batch": ("pod", "data"),  # caches keep batch sharding always
    "tile_q": ("model",),     # attn-tile fallback when heads don't divide
    "vocab": ("model",),
    "heads": ("model",),
    "qkv": ("model",),        # flattened (n_heads * head_dim) projections
    "ffn": ("model",),
    "experts": ("model",),
    "embed": ("data",),       # FSDP: stacked params sharded over data
    "embed_d": ("data",),     # the embedding/unembedding tables' d_model axis
    "ssm_inner": ("model",),
    "layers": (),
    "state": (),
    "none": (),
}

# Sharding profiles (§Perf hillclimb levers; see EXPERIMENTS.md):
#  baseline : FSDP everywhere, decode-SP caches -- the paper-faithful start
#  opt1     : baseline minus FSDP on the (un)embedding tables, whose data-axis
#             shards were re-gathered every loss chunk
#  serve    : inference layout -- 2D tensor parallelism on weights (no
#             contraction-dim sharding => no per-layer weight all-gathers at
#             tiny token counts), KV caches sharded on head_dim instead of
#             sequence (local cache updates, cheap partial-softmax reductions)
PROFILES: dict[str, dict[str, tuple[str, ...]]] = {
    "baseline": {},
    "opt1": {"embed_d": ()},
    # moe_ep: for MoE archs whose expert count does not divide the 16-way
    # model axis (mixtral: 8), run on the (data=16, expert=8, tp=2) mesh --
    # experts get a true EP axis, dense layers use (expert x tp) as a 16-way
    # model axis, and the dispatched tensor stays fully sharded end-to-end.
    "moe_ep": {
        "experts": ("expert",),
        "heads": ("expert", "tp"),
        "qkv": ("expert", "tp"),
        "ffn": ("tp",),
        "vocab": ("expert", "tp"),
        "seq": ("expert", "tp"),
        "cache_seq": ("expert", "tp"),
        "ssm_inner": ("expert", "tp"),
        "tile_q": ("expert", "tp"),
        "embed_d": (),
    },
    # serve: weights live resident in a 2D (model x data) layout -- no
    # contraction-dim sharding, so no per-step weight all-gathers; the tiny
    # decode activations REPLICATE over the data axis (batch: ()) instead of
    # dragging 100x their size in weight movement; KV caches keep batch+seq
    # sharding (cache_batch/cache_seq) since they dominate memory.
    "serve": {
        "batch": (),
        "seq": (),
        "embed_d": (),
        "embed": (),
        "qkv": ("model", "data"),
        "ffn": ("model", "data"),
        "vocab": ("model", "data"),
        "ssm_inner": ("model", "data"),
    },
}
_DEFAULT_RULES = dict(LOGICAL_RULES)


def set_sharding_profile(name: str) -> None:
    """Switch the logical->mesh rules table (mutates module state; the
    launcher selects 'serve' for prefill/decode cells, 'opt1' for training
    after the §Perf iteration validated it)."""
    LOGICAL_RULES.clear()
    LOGICAL_RULES.update(_DEFAULT_RULES)
    LOGICAL_RULES.update(PROFILES[name])


@dataclasses.dataclass(frozen=True)
class PSpec:
    """One parameter leaf: shape + logical axes + initializer."""
    shape: tuple[int, ...]
    logical: tuple[str, ...]
    init: str = "fan_in"      # fan_in | zeros | ones | embed | a_log | dt_bias
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def tree_map_pspec(fn: Callable[[str, PSpec], Any], tree, path: str = "") -> Any:
    if is_pspec(tree):
        return fn(path, tree)
    if isinstance(tree, dict):
        return {k: tree_map_pspec(fn, v, f"{path}/{k}") for k, v in tree.items()}
    raise TypeError(type(tree))


def _initialize(key: jax.Array, p: PSpec, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "a_log":  # mamba2: A ~ U[1,16], stored as log
        u = jax.random.uniform(key, p.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if p.init == "dt_bias":  # mamba2: softplus^-1 of dt ~ logU[1e-3, 1e-1]
        u = jax.random.uniform(key, p.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if p.init == "embed":
        return (jax.random.normal(key, p.shape, jnp.float32) * 0.02).astype(dtype)
    # fan_in: truncated-normal-ish with 1/sqrt(fan_in); fan-in = first axis
    # that is not a stacking ("layers") axis
    fan = 1
    for s, l in zip(p.shape, p.logical):
        if l != "layers":
            fan = s
            break
    scale = 1.0 / math.sqrt(max(fan, 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dtype)


def init_params(spec_tree, key: jax.Array, param_dtype=jnp.float32):
    """Materialize real parameters (deterministic per-path key folding)."""
    leaves = []
    tree_map_pspec(lambda path, p: leaves.append(path), spec_tree)
    idx = {path: i for i, path in enumerate(sorted(leaves))}

    def make(path, p):
        k = jax.random.fold_in(key, idx[path])
        return _initialize(k, p, param_dtype)

    return tree_map_pspec(make, spec_tree)


def abstract_params(spec_tree, param_dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins: weak-type-correct, zero allocation."""
    return tree_map_pspec(
        lambda _, p: jax.ShapeDtypeStruct(p.shape, param_dtype), spec_tree
    )


# ----------------------------------------------------------------- shardings
def resolve_spec(shape: tuple[int, ...], logical: tuple[str, ...], mesh_shape: dict[str, int]) -> PartitionSpec:
    """Logical axes -> PartitionSpec with divisibility degradation."""
    cands = [LOGICAL_RULES.get(lname, ()) for lname in logical]
    return degrade_spec(shape, cands, mesh_shape)


def param_shardings(spec_tree, mesh: jax.sharding.Mesh):
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    return tree_map_pspec(
        lambda _, p: NamedSharding(mesh, resolve_spec(p.shape, p.logical, ms)),
        spec_tree,
    )


def logical_pspecs(spec_tree, mesh_shape: dict[str, int]):
    return tree_map_pspec(
        lambda _, p: resolve_spec(p.shape, p.logical, mesh_shape), spec_tree
    )


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """Sharding constraint by logical axis names, no-op outside a mesh context.

    Activations use this (params are sharded via in_shardings).  Degradation:
    an axis that does not divide is dropped, so every architecture compiles on
    every mesh.
    """
    ms = current_axis_sizes()
    if not ms:
        return x
    spec = resolve_spec(x.shape, tuple(l or "none" for l in logical), ms)
    return constrain_spec(x, spec)
