"""Model substrate plumbing: spec-first parameters and logical-axis sharding.

Spec-first parameters: model builders return a *tree of PSpec* (shape + logical
axis names + init kind).  The tree is materialized three ways:
  * ``init_params``      -> real arrays (training / smoke tests)
  * ``abstract_params``  -> ShapeDtypeStruct (the multi-pod dry-run: no bytes)
  * ``param_shardings``  -> NamedSharding per leaf from the logical rules

Logical-axis sharding with divisibility degradation (DESIGN.md §5): a logical
axis maps to mesh axes only when the dimension is divisible by their product,
so minicpm's 36 heads stay replicated on a 16-way model axis while llama's 128
heads shard -- one rules table serves all ten architectures.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
import warnings
from types import MappingProxyType
from typing import Any, Callable, Iterator, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..substrate import constrain_spec, current_axis_sizes, degrade_spec

# logical axis name -> preferred mesh axes (applied greedily, outermost first).
# This is the *baseline* table; profile overlays never mutate it.  No module
# outside models/common.py may read or write this dict (scripts/ci.sh greps) --
# consumers go through the active ShardingProfile instead.
LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),        # sequence-parallel residual stream (train/prefill)
    "cache_seq": ("model",),  # decode-SP: KV cache sharded along sequence
    "cache_hd": (),           # alternative: cache head_dim sharding
    "cache_batch": ("pod", "data"),  # caches keep batch sharding always
    "tile_q": ("model",),     # attn-tile fallback when heads don't divide
    "vocab": ("model",),
    "heads": ("model",),
    "qkv": ("model",),        # flattened (n_heads * head_dim) projections
    "ffn": ("model",),
    "experts": ("model",),
    "embed": ("data",),       # FSDP: stacked params sharded over data
    "embed_d": ("data",),     # the embedding/unembedding tables' d_model axis
    "ssm_inner": ("model",),
    "layers": (),
    "state": (),
    "none": (),
}

# Sharding profiles (§Perf hillclimb levers; see EXPERIMENTS.md):
#  baseline : FSDP everywhere, decode-SP caches -- the paper-faithful start
#  opt1     : baseline minus FSDP on the (un)embedding tables, whose data-axis
#             shards were re-gathered every loss chunk
#  serve    : inference layout -- 2D tensor parallelism on weights (no
#             contraction-dim sharding => no per-layer weight all-gathers at
#             tiny token counts), KV caches sharded on head_dim instead of
#             sequence (local cache updates, cheap partial-softmax reductions)
PROFILES: dict[str, dict[str, tuple[str, ...]]] = {
    "baseline": {},
    "opt1": {"embed_d": ()},
    # moe_ep: for MoE archs whose expert count does not divide the 16-way
    # model axis (mixtral: 8), run on the (data=16, expert=8, tp=2) mesh --
    # experts get a true EP axis, dense layers use (expert x tp) as a 16-way
    # model axis, and the dispatched tensor stays fully sharded end-to-end.
    "moe_ep": {
        "experts": ("expert",),
        "heads": ("expert", "tp"),
        "qkv": ("expert", "tp"),
        "ffn": ("tp",),
        "vocab": ("expert", "tp"),
        "seq": ("expert", "tp"),
        "cache_seq": ("expert", "tp"),
        "ssm_inner": ("expert", "tp"),
        "tile_q": ("expert", "tp"),
        "embed_d": (),
    },
    # serve: weights live resident in a 2D (model x data) layout -- no
    # contraction-dim sharding, so no per-step weight all-gathers; the tiny
    # decode activations REPLICATE over the data axis (batch: ()) instead of
    # dragging 100x their size in weight movement; KV caches keep batch+seq
    # sharding (cache_batch/cache_seq) since they dominate memory.
    "serve": {
        "batch": (),
        "seq": (),
        "embed_d": (),
        "embed": (),
        "qkv": ("model", "data"),
        "ffn": ("model", "data"),
        "vocab": ("model", "data"),
        "ssm_inner": ("model", "data"),
    },
}


def profile_names() -> list[str]:
    """Registry-derived profile names, the single source of truth for CLI
    ``--profile`` choices.  Launchers must consume this instead of re-listing
    the names (ci.sh greps for drift), so adding a profile here updates every
    CLI at once."""
    return sorted(PROFILES)


@dataclasses.dataclass(frozen=True)
class ShardingProfile:
    """An immutable, fully-resolved logical->mesh rules table.

    The paper's point in miniature: a partial schedule (here, a sharding
    layout) is only meaningful together with the mapping that produced it.
    A profile therefore carries the *complete* table (baseline rules with the
    named overlay applied), never a diff against mutable module state, so two
    profiles can be active in the same process without racing.
    """
    name: str
    rules: Mapping[str, tuple[str, ...]]

    def rule(self, logical: str) -> tuple[str, ...]:
        return self.rules.get(logical, ())


def _build_profile(name: str) -> ShardingProfile:
    if name not in PROFILES:
        raise KeyError(
            f"unknown sharding profile {name!r}; known: {sorted(PROFILES)}")
    return ShardingProfile(name, MappingProxyType({**LOGICAL_RULES,
                                                   **PROFILES[name]}))


_PROFILE_CACHE: dict[str, ShardingProfile] = {}


def resolve_profile(profile: str | ShardingProfile) -> ShardingProfile:
    """Name or profile -> ShardingProfile.  Raises KeyError on an unknown
    name *before* any state changes, so a failed lookup never corrupts the
    active profile (the latent bug in the old global-mutation path)."""
    if isinstance(profile, ShardingProfile):
        return profile
    if profile not in _PROFILE_CACHE:
        _PROFILE_CACHE[profile] = _build_profile(profile)
    return _PROFILE_CACHE[profile]


# contextvars give per-thread AND per-async-task scoping: each thread (and
# each asyncio task) sees only the profiles entered on its own stack.
_ACTIVE_PROFILE: contextvars.ContextVar[ShardingProfile | None] = \
    contextvars.ContextVar("repro_sharding_profile", default=None)
# process-wide fallback for the deprecated set_sharding_profile() shim;
# scoped sharding_profile(...) blocks always take precedence
_PROCESS_DEFAULT_PROFILE: ShardingProfile | None = None


def active_profile() -> ShardingProfile:
    """The profile rule lookups use when none is passed explicitly:
    innermost ``sharding_profile`` block on this thread/task, else the
    process default set by the deprecated shim, else baseline."""
    prof = _ACTIVE_PROFILE.get()
    if prof is not None:
        return prof
    if _PROCESS_DEFAULT_PROFILE is not None:
        return _PROCESS_DEFAULT_PROFILE
    return resolve_profile("baseline")


@contextlib.contextmanager
def sharding_profile(profile: str | ShardingProfile) -> Iterator[ShardingProfile]:
    """Scoped profile selection::

        with sharding_profile("serve") as prof:
            shardings = param_shardings(specs, mesh)

    Nesting replaces (does not merge): the innermost profile's full table
    wins, and exiting restores the enclosing profile -- guaranteed by
    try/finally even when the body raises.  Thread- and async-safe.
    """
    prof = resolve_profile(profile)  # validate before touching any state
    token = _ACTIVE_PROFILE.set(prof)
    try:
        yield prof
    finally:
        _ACTIVE_PROFILE.reset(token)


def set_sharding_profile(name: str) -> None:
    """DEPRECATED shim: sets the process-wide *default* profile.

    Use ``sharding_profile(name)`` instead -- the scoped form composes under
    concurrency; this one is a process-global and any active scoped profile
    overrides it.  Inherits the restoration guarantee of the scoped path: an
    unknown name raises before the default changes, and no shared table is
    ever mutated, so there is no corrupt intermediate state to restore."""
    warnings.warn(
        "set_sharding_profile() is deprecated; use the scoped "
        "`with sharding_profile(name):` context manager",
        DeprecationWarning, stacklevel=2)
    prof = resolve_profile(name)
    global _PROCESS_DEFAULT_PROFILE
    _PROCESS_DEFAULT_PROFILE = prof


@dataclasses.dataclass(frozen=True)
class PSpec:
    """One parameter leaf: shape + logical axes + initializer."""
    shape: tuple[int, ...]
    logical: tuple[str, ...]
    init: str = "fan_in"      # fan_in | zeros | ones | embed | a_log | dt_bias
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def tree_map_pspec(fn: Callable[[str, PSpec], Any], tree, path: str = "") -> Any:
    if is_pspec(tree):
        return fn(path, tree)
    if isinstance(tree, dict):
        return {k: tree_map_pspec(fn, v, f"{path}/{k}") for k, v in tree.items()}
    raise TypeError(type(tree))


def _initialize(key: jax.Array, p: PSpec, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "a_log":  # mamba2: A ~ U[1,16], stored as log
        u = jax.random.uniform(key, p.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if p.init == "dt_bias":  # mamba2: softplus^-1 of dt ~ logU[1e-3, 1e-1]
        u = jax.random.uniform(key, p.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if p.init == "embed":
        return (jax.random.normal(key, p.shape, jnp.float32) * 0.02).astype(dtype)
    # fan_in: truncated-normal-ish with 1/sqrt(fan_in); fan-in = first axis
    # that is not a stacking ("layers") axis
    fan = 1
    for s, l in zip(p.shape, p.logical):
        if l != "layers":
            fan = s
            break
    scale = 1.0 / math.sqrt(max(fan, 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dtype)


def init_params(spec_tree, key: jax.Array, param_dtype=jnp.float32):
    """Materialize real parameters (deterministic per-path key folding)."""
    leaves = []
    tree_map_pspec(lambda path, p: leaves.append(path), spec_tree)
    idx = {path: i for i, path in enumerate(sorted(leaves))}

    def make(path, p):
        k = jax.random.fold_in(key, idx[path])
        return _initialize(k, p, param_dtype)

    return tree_map_pspec(make, spec_tree)


def abstract_params(spec_tree, param_dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins: weak-type-correct, zero allocation."""
    return tree_map_pspec(
        lambda _, p: jax.ShapeDtypeStruct(p.shape, param_dtype), spec_tree
    )


# ----------------------------------------------------------------- shardings
def resolve_spec(shape: tuple[int, ...], logical: tuple[str, ...],
                 mesh_shape: dict[str, int],
                 profile: str | ShardingProfile | None = None) -> PartitionSpec:
    """Logical axes -> PartitionSpec with divisibility degradation.

    Rules come from ``profile`` when given, else from the active scoped
    profile (``sharding_profile``), else the process default."""
    prof = resolve_profile(profile) if profile is not None else active_profile()
    cands = [prof.rule(lname) for lname in logical]
    return degrade_spec(shape, cands, mesh_shape)


def param_shardings(spec_tree, mesh: jax.sharding.Mesh,
                    profile: str | ShardingProfile | None = None):
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    prof = resolve_profile(profile) if profile is not None else active_profile()
    return tree_map_pspec(
        lambda _, p: NamedSharding(
            mesh, resolve_spec(p.shape, p.logical, ms, profile=prof)),
        spec_tree,
    )


def logical_pspecs(spec_tree, mesh_shape: dict[str, int],
                   profile: str | ShardingProfile | None = None):
    prof = resolve_profile(profile) if profile is not None else active_profile()
    return tree_map_pspec(
        lambda _, p: resolve_spec(p.shape, p.logical, mesh_shape, profile=prof),
        spec_tree,
    )


def constrain(x: jax.Array, *logical: str | None,
              profile: str | ShardingProfile | None = None) -> jax.Array:
    """Sharding constraint by logical axis names, no-op outside a mesh context.

    Activations use this (params are sharded via in_shardings).  Degradation:
    an axis that does not divide is dropped, so every architecture compiles on
    every mesh.  The profile is read at trace time, so the jit wrapper must be
    entered under the same profile every call (Engine/Trainer pin theirs).
    """
    ms = current_axis_sizes()
    if not ms:
        return x
    spec = resolve_spec(x.shape, tuple(l or "none" for l in logical), ms,
                        profile=profile)
    return constrain_spec(x, spec)
