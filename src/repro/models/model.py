"""Model facade: one object per architecture exposing spec trees, init,
loss/prefill/decode functions and input specs for every shape cell."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeCell
from . import encdec, transformer
from .common import abstract_params, init_params, param_shardings


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------------ params
    def specs(self):
        if self.cfg.family == "encdec":
            return encdec.model_specs(self.cfg)
        return transformer.model_specs(self.cfg)

    def init(self, key: jax.Array):
        return init_params(self.specs(), key, jnp.dtype(self.cfg.param_dtype))

    def abstract(self):
        return abstract_params(self.specs(), jnp.dtype(self.cfg.param_dtype))

    def shardings(self, mesh):
        return param_shardings(self.specs(), mesh)

    def cache_specs(self, batch: int, seq: int):
        if self.cfg.family == "encdec":
            return encdec.cache_specs(self.cfg, batch, seq)
        return transformer.cache_specs(self.cfg, batch, seq)

    # ------------------------------------------------------------------- steps
    def loss(self, params, batch) -> jax.Array:
        """batch: tokens/labels (+ frames for encdec, embeds/positions for vlm)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            l, aux = encdec.loss(params, cfg, batch["frames"], batch["tokens"],
                                 batch["labels"])
            return l + aux
        hidden, aux, _ = transformer.forward_full(
            params, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            positions=batch.get("positions"),
        )
        return transformer.xent_loss(params, cfg, hidden, batch["labels"]) + aux

    def prefill(self, params, batch):
        """Returns (per-layer cache stacked over periods, last-token logits)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            enc_out = encdec.encode(params, cfg, batch["frames"])
            hidden, cache = encdec.decode_full(params, cfg, batch["tokens"],
                                               enc_out, want_cache=True)
            logits = (hidden[:, -1:] @ params["unembed"].astype(hidden.dtype))
            return cache, logits.astype(jnp.float32)
        hidden, _, cache = transformer.forward_full(
            params, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            positions=batch.get("positions"),
            want_cache=True,
        )
        logits = transformer.unembed(params, cfg, hidden[:, -1:])
        return cache, logits

    def decode(self, params, cache, tokens, pos, positions=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.decode_step(params, cfg, cache, tokens, pos)
        logits, new_cache = transformer.decode_step(
            params, cfg, cache, tokens=tokens, pos=pos, positions=positions
        )
        return logits, new_cache

    # ------------------------------------------------------------- input specs
    def input_specs(self, cell: ShapeCell) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell
        (the dry-run contract: weak-type-correct, shardable, no allocation)."""
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        f32 = jnp.float32
        tok = jax.ShapeDtypeStruct((B, S), i32)
        if cell.kind == "train":
            if cfg.family == "encdec":
                return {"frames": jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), f32),
                        "tokens": tok, "labels": tok}
            out = {"tokens": tok, "labels": tok}
            if cfg.family == "vlm":
                out = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f32),
                       "labels": tok,
                       "positions": jax.ShapeDtypeStruct((3, B, S), i32)}
            return out
        if cell.kind == "prefill":
            if cfg.family == "encdec":
                return {"frames": jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), f32),
                        "tokens": tok}
            if cfg.family == "vlm":
                return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f32),
                        "positions": jax.ShapeDtypeStruct((3, B, S), i32)}
            return {"tokens": tok}
        # decode: one new token against a seq_len cache
        out = {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
               "pos": jax.ShapeDtypeStruct((), i32)}
        if cfg.family == "vlm":
            out["positions"] = jax.ShapeDtypeStruct((3, B, 1), i32)
        return out


def build(cfg: ArchConfig) -> Model:
    return Model(cfg)
