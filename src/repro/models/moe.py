"""GShard-style token-choice MoE (einsum dispatch, capacity-factor drops).

Tokens are processed in *groups* (a sequence slice) so the dispatch/combine
tensors stay O(tokens x E x C) with C = cf * group * k / E -- the group size
bounds the quadratic dispatch-einsum cost to a few percent of expert FLOPs
(group 256: E*C ~ 2.5 * 256 vs d_ff contraction; see EXPERIMENTS.md §Roofline
"useful-FLOPs ratio").

Expert placement: true EP (experts sharded over the model axis) when the
expert count divides it (dbrx/jamba: 16); otherwise tensor-parallel experts
(d_ff over model; mixtral: 8 experts on a 16-way axis).  The divisibility
degradation in common.resolve_spec picks this automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import PSpec, constrain

AUX_COEF = 0.01
GROUP = 256


def moe_specs(cfg: ArchConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    assert cfg.mlp_style == "swiglu", "MoE experts are SwiGLU"
    return {
        "router": PSpec((d, e), ("embed", "experts")),
        "wg": PSpec((e, d, ff), ("experts", "embed", "ffn")),
        "wu": PSpec((e, d, ff), ("experts", "embed", "ffn")),
        "wd": PSpec((e, ff, d), ("experts", "ffn", "embed")),
    }


def moe(p, x, cfg: ArchConfig):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    gs = min(GROUP, S)
    nG = S // gs
    assert S % gs == 0, (S, gs)
    C = max(1, int(cfg.capacity_factor * gs * K / E))

    xg = x.reshape(B, nG, gs, D)
    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (B,nG,gs,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                               # (B,nG,gs,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    mask = jax.nn.one_hot(idx, E, dtype=jnp.float32)                  # (B,nG,gs,K,E)
    # position of each (token, k) within its expert's capacity, per group
    flat = mask.reshape(B, nG, gs * K, E)
    pos = jnp.cumsum(flat, axis=2) - 1.0
    pos = pos.reshape(B, nG, gs, K, E)
    keep = (pos < C) & (mask > 0)
    pos = jnp.clip(pos, 0, C - 1).astype(jnp.int32)

    # combine[b,g,s,e,c] = sum_k gate_k * keep * onehot(pos, C)
    poh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]  # (B,nG,gs,K,E,C)
    combine = jnp.einsum("bgsk,bgskec->bgsec", gate, poh)
    dispatch = (combine > 0).astype(x.dtype)                           # (B,nG,gs,E,C)

    xe = jnp.einsum("bgsec,bgsd->begcd", dispatch, xg)                 # (B,E,nG,C,D)
    # experts shard the model axis when the count divides (true EP); otherwise
    # the group axis keeps it, so the dispatched tensor never de-shards
    xe = constrain(xe, "batch", "experts", "seq", None, None)
    wg = p["wg"].astype(x.dtype)
    wu = p["wu"].astype(x.dtype)
    wd = p["wd"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("begcd,edf->begcf", xe, wg))
    h = h * jnp.einsum("begcd,edf->begcf", xe, wu)
    h = constrain(h, "batch", "experts", "seq", None, "ffn")
    ye = jnp.einsum("begcf,efd->begcd", h, wd)                         # (B,E,nG,C,D)
    # pin ye to the dispatched layout so the combine-einsum backward does not
    # hit SPMD's involuntary-full-rematerialization path (XLA b/433785288)
    ye = constrain(ye, "batch", "experts", "seq", None, None)
    out = jnp.einsum("bgsec,begcd->bgsd", combine.astype(x.dtype), ye)
    out = out.reshape(B, S, D)

    # Switch-style load-balance loss: E * sum_e f_e * p_e (per group, meaned)
    f = mask.sum(3).mean(2)          # (B,nG,E): fraction routed (pre-drop)
    pbar = probs.mean(2)             # (B,nG,E)
    aux = AUX_COEF * E * jnp.mean(jnp.sum(f * pbar, axis=-1))
    return constrain(out, "batch", "seq", None), aux
