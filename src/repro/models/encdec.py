"""Encoder-decoder (Whisper-style) stack.

The audio conv frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, T_enc, D).  Sinusoidal absolute positions on
both sides (no RoPE), GELU 2-proj MLPs, MHA.  Decode keeps a self-attn KV
cache (sized to the shape cell) plus fixed cross-attn K/V over the encoder
output.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import PSpec, constrain
from .layers import (
    attn_decode,
    attn_prefill,
    attn_specs,
    chunked_attention,
    mlp,
    mlp_specs,
    rmsnorm,
    rmsnorm_spec,
    sinusoidal_embedding,
)
from .transformer import stack_specs, xent_loss  # noqa: F401  (xent reused)


def enc_block_specs(cfg: ArchConfig) -> dict:
    return {
        "norm1": rmsnorm_spec(cfg.d_model),
        "attn": attn_specs(cfg),
        "norm2": rmsnorm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def dec_block_specs(cfg: ArchConfig) -> dict:
    return {
        "norm1": rmsnorm_spec(cfg.d_model),
        "self_attn": attn_specs(cfg),
        "norm_x": rmsnorm_spec(cfg.d_model),
        "cross_attn": attn_specs(cfg),
        "norm2": rmsnorm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def model_specs(cfg: ArchConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab
    return {
        "embed": PSpec((V, d), ("vocab", "embed_d"), init="embed"),
        "enc_norm": rmsnorm_spec(d),
        "final_norm": rmsnorm_spec(d),
        "enc_blocks": stack_specs(enc_block_specs(cfg), cfg.enc_layers),
        "dec_blocks": stack_specs(dec_block_specs(cfg), cfg.n_layers),
        "unembed": PSpec((d, V), ("embed_d", "vocab")),
    }


def cache_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    kv = lambda s: PSpec(
        (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.hd),
        ("layers", "cache_batch", "cache_seq", "heads", "cache_hd"),
        init="zeros", dtype=cfg.compute_dtype,
    )
    return {"self": {"k": kv(seq), "v": kv(seq)},
            "cross": {"k": kv(cfg.enc_seq), "v": kv(cfg.enc_seq)}}


def encode(params, cfg: ArchConfig, frames):
    """frames: (B, T, D) stub embeddings -> (B, T, D) encoder states."""
    B, T, D = frames.shape
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + sinusoidal_embedding(T, D).astype(x.dtype)[None]
    x = constrain(x, "batch", "seq", None)

    def body(x, bp):
        h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
        a, _ = attn_prefill(bp["attn"], h, cfg, None, causal=False)
        x = x + a
        h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
        return x + mlp(bp["mlp"], h, cfg), None

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(bp, enc_out, cfg: ArchConfig):
    B, T, _ = enc_out.shape
    k = (enc_out @ bp["cross_attn"]["wk"].astype(enc_out.dtype)).reshape(
        B, T, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ bp["cross_attn"]["wv"].astype(enc_out.dtype)).reshape(
        B, T, cfg.n_kv_heads, cfg.hd)
    return k, v


def _cross_attend(bp, h, k, v, cfg: ArchConfig):
    B, S, D = h.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (h @ bp["cross_attn"]["wq"].astype(h.dtype)).reshape(B, S, hq, hd)
    qh = jnp.moveaxis(q.reshape(B, S, hkv, hq // hkv, hd), 1, 3)
    out = chunked_attention(qh, k.astype(h.dtype), v.astype(h.dtype), causal=False)
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, hq * hd)
    return out @ bp["cross_attn"]["wo"].astype(h.dtype)


def decode_full(params, cfg: ArchConfig, tokens, enc_out, want_cache=False):
    """Teacher-forced decoder pass (training / prefill)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    x = x + sinusoidal_embedding(S, cfg.d_model).astype(x.dtype)[None]
    x = constrain(x, "batch", "seq", None)

    def body(x, bp):
        h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
        a, (k, v) = attn_prefill(bp["self_attn"], h, cfg, None, causal=True)
        x = x + a
        h = rmsnorm(bp["norm_x"], x, cfg.norm_eps)
        ck, cv = _cross_kv(bp, enc_out, cfg)
        x = x + _cross_attend(bp, h, ck, cv, cfg)
        h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
        x = x + mlp(bp["mlp"], h, cfg)
        cache = {"self": {"k": k, "v": v}, "cross": {"k": ck, "v": cv}}
        return x, (cache if want_cache else 0)

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, caches = jax.lax.scan(body_fn, x, params["dec_blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, (caches if want_cache else None)


def loss(params, cfg: ArchConfig, frames, tokens, labels):
    enc_out = encode(params, cfg, frames)
    hidden, _ = decode_full(params, cfg, tokens, enc_out)
    return xent_loss(params, cfg, hidden, labels), jnp.zeros((), jnp.float32)


def decode_step(params, cfg: ArchConfig, cache, tokens, pos):
    """One decoder token.  cache: {self: {k,v (L,B,Sc,H,hd)}, cross: {...}}."""
    B, _ = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    pe = sinusoidal_embedding(1, cfg.d_model, offset=0).astype(x.dtype)
    # offset by pos dynamically: recompute the single sinusoid row at `pos`
    d = cfg.d_model
    inv = 1e4 ** (-jnp.arange(0, d // 2, dtype=jnp.float32) / (d // 2 - 1 + 1e-9))
    ang = pos.astype(jnp.float32) * inv
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(x.dtype)
    x = x + pe

    def body(x, scanned):
        bp, pc = scanned
        h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
        a, nself = attn_decode(bp["self_attn"], h, cfg, pc["self"], pos, None)
        x = x + a
        h = rmsnorm(bp["norm_x"], x, cfg.norm_eps)
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = (h @ bp["cross_attn"]["wq"].astype(h.dtype)).reshape(B, 1, hq, hd)
        qh = jnp.moveaxis(q.reshape(B, 1, hkv, hq // hkv, hd), 1, 3)
        ck, cv = pc["cross"]["k"].astype(h.dtype), pc["cross"]["v"].astype(h.dtype)
        co = chunked_attention(qh, ck, cv, causal=False)
        co = jnp.moveaxis(co, 3, 1).reshape(B, 1, hq * hd)
        x = x + co @ bp["cross_attn"]["wo"].astype(h.dtype)
        h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
        x = x + mlp(bp["mlp"], h, cfg)
        return x, {"self": nself, "cross": pc["cross"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["unembed"].astype(x.dtype)).astype(jnp.float32)
    return logits, new_cache
