"""Pure-JAX model layers: norms, RoPE / M-RoPE, memory-linear attention
(online-softmax chunking), GQA/SWA, decode-step attention, MLPs.

Layout conventions:
  activations x : (B, S, D)
  q heads       : (B, Hkv, G, S, hd)  with G = Hq // Hkv (GQA groups)
  kv            : (B, S, Hkv, hd)     (cache layout: seq second for decode-SP)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import PSpec, constrain

NEG_INF = -1e30


def cast(x, dtype_str):
    return x.astype(jnp.dtype(dtype_str))


# ------------------------------------------------------------------------ norms
def rmsnorm_spec(d: int) -> PSpec:
    return PSpec((d,), ("none",), init="ones")


def rmsnorm(w, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w).astype(x.dtype)


# ------------------------------------------------------------------------- RoPE
def _rope_angles(positions, n_freq: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, n_freq)."""
    inv = theta ** (-jnp.arange(0, n_freq, dtype=jnp.float32) / n_freq)
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def rope_cos_sin(cfg: ArchConfig, positions):
    """positions: (B, S) int32, or (3, B, S) for M-RoPE.

    M-RoPE (Qwen2-VL): the head_dim/2 frequencies are split into
    (temporal, h, w) sections, each rotated by its own position id.
    """
    half = cfg.hd // 2
    if cfg.mrope:
        assert positions.ndim == 3, "M-RoPE wants (3, B, S) positions"
        secs = cfg.mrope_sections
        assert sum(secs) == half, (secs, half)
        # per-frequency position: frequencies [0:t) use temporal ids, etc.
        rep = jnp.repeat(jnp.arange(3), jnp.asarray(secs), total_repeat_length=half)
        pos = positions[rep, :, :]                      # (half, B, S)
        pos = jnp.moveaxis(pos, 0, -1)                  # (B, S, half)
        inv = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
        ang = pos.astype(jnp.float32) * inv
        return jnp.cos(ang), jnp.sin(ang)
    return _rope_angles(positions, half, cfg.rope_theta)  # (B, S, half)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) (split-half convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def sinusoidal_embedding(S: int, d: int, offset: int = 0):
    """Whisper-style absolute sinusoidal positions (B-broadcastable (S, d))."""
    pos = jnp.arange(offset, offset + S, dtype=jnp.float32)[:, None]
    inv = 1e4 ** (-jnp.arange(0, d // 2, dtype=jnp.float32) / (d // 2 - 1 + 1e-9))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -------------------------------------------------------------------- attention
def attn_specs(cfg: ArchConfig) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": PSpec((d, hq * hd), ("embed", "qkv")),
        "wk": PSpec((d, hkv * hd), ("embed", "qkv")),
        "wv": PSpec((d, hkv * hd), ("embed", "qkv")),
        "wo": PSpec((hq * hd, d), ("qkv", "embed")),
    }


def qkv_proj(p, x, cfg: ArchConfig, cos_sin=None):
    """x (B,S,D) -> q (B,S,Hq,hd), k,v (B,S,Hkv,hd), RoPE applied."""
    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, hq, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, hkv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, hkv, hd)
    if cos_sin is not None:
        cos, sin = cos_sin
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def chunked_attention(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    kv_len: Optional[int] = None,
    q_chunk: int = 512,
    k_chunk: int = 1024,
):
    """Flash-style online-softmax attention in pure XLA: O(S) memory.

    q: (B, Hkv, G, Sq, hd); k, v: (B, Sk, Hkv, hd).
    kv_len: number of valid keys (<= Sk) for padded caches.
    Never materializes (Sq, Sk); the working set is (qc, kc) score tiles --
    exactly the shape XLA:TPU fuses into VMEM-resident loops.
    """
    B, Hk, G, Sq, hd = q.shape
    Sk = k.shape[1]
    kv_len = Sk if kv_len is None else kv_len
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    pad_q = (-Sq) % qc
    pad_k = (-Sk) % kc
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = (Sq + pad_q) // qc, (Sk + pad_k) // kc
    scale = 1.0 / math.sqrt(hd)
    kT = jnp.moveaxis(k, 1, 3)  # (B, Hkv, hd, Skp)
    vT = jnp.moveaxis(v, 1, 2)  # (B, Hkv, Skp, hd)

    q_blocks = jnp.moveaxis(q.reshape(B, Hk, G, nq, qc, hd), 3, 0)  # (nq,B,Hk,G,qc,hd)

    def per_q(qi, qb):
        qpos = q_offset + qi * qc + jnp.arange(qc)

        def per_k(carry, ki):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kT, ki * kc, kc, axis=3)
            vb = jax.lax.dynamic_slice_in_dim(vT, ki * kc, kc, axis=2)
            s = jnp.einsum("bhgqd,bhdk->bhgqk", qb, kb) * scale
            s = s.astype(jnp.float32)
            kpos = ki * kc + jnp.arange(kc)
            mask = kpos[None, :] < kv_len
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m2 = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m2)
            pexp = jnp.exp(s - m2[..., None])
            l2 = l * alpha + pexp.sum(axis=-1)
            acc2 = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", pexp.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (m2, l2, acc2), None

        init = (
            jnp.full((B, Hk, G, qc), NEG_INF, jnp.float32),
            jnp.zeros((B, Hk, G, qc), jnp.float32),
            jnp.zeros((B, Hk, G, qc, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(per_k, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return out.astype(q.dtype)

    outs = jax.lax.map(lambda args: per_q(*args), (jnp.arange(nq), q_blocks))
    out = jnp.moveaxis(outs, 0, 3).reshape(B, Hk, G, Sq + pad_q, hd)
    return out[:, :, :, :Sq]


def attn_prefill(p, x, cfg: ArchConfig, cos_sin, *, window: int = 0, causal=True):
    """Full-sequence attention; returns (out, (k, v)) for cache seeding."""
    B, S, D = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = qkv_proj(p, x, cfg, cos_sin)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    qh = jnp.moveaxis(q.reshape(B, S, hkv, hq // hkv, hd), 1, 3)  # (B,Hkv,G,S,hd)
    out = chunked_attention(qh, k, v, causal=causal, window=window)
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, hq * hd)
    out = out @ p["wo"].astype(x.dtype)
    return constrain(out, "batch", "seq", None), (k, v)


def attn_decode(p, x, cfg: ArchConfig, cache, pos, cos_sin, *, window: int = 0):
    """One-token step: update cache at pos (ring slot for SWA), attend.

    x: (B, 1, D); cache: dict(k=(B, Sc, Hkv, hd), v=...); pos: scalar int32.
    """
    B, _, D = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = qkv_proj(p, x, cfg, cos_sin)
    Sc = cache["k"].shape[1]
    # window (static): ring-buffer slot; else absolute position
    slot = (pos % Sc if window > 0 else pos).astype(jnp.int32)
    # align the one-token update with the cache layout BEFORE the
    # dynamic_update_slice: a sharding mismatch here makes SPMD rematerialize
    # the whole cache (measured 292 MB/layer on llama3-405b decode, §Perf it3)
    k = constrain(k.astype(cache["k"].dtype), "cache_batch", None, "heads", "cache_hd")
    v = constrain(v.astype(cache["v"].dtype), "cache_batch", None, "heads", "cache_hd")
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    qh = jnp.moveaxis(q.reshape(B, 1, hkv, hq // hkv, hd), 1, 3)  # (B,Hkv,G,1,hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhgqd,bshd->bhgqs", qh, ck.astype(qh.dtype)) * scale
    s = s.astype(jnp.float32)
    idx = jnp.arange(Sc)
    valid = idx < jnp.minimum(pos + 1, Sc) if window > 0 else idx <= pos
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqs,bshd->bhgqd", w, cv.astype(x.dtype))
    out = jnp.moveaxis(out, 3, 1).reshape(B, 1, hq * hd)
    out = out @ p["wo"].astype(x.dtype)
    return out, {"k": ck, "v": cv}


# ------------------------------------------------------------------------- MLPs
def mlp_specs(cfg: ArchConfig, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_style == "swiglu":
        return {
            "wg": PSpec((d, ff), ("embed", "ffn")),
            "wu": PSpec((d, ff), ("embed", "ffn")),
            "wd": PSpec((ff, d), ("ffn", "embed")),
        }
    return {
        "w1": PSpec((d, ff), ("embed", "ffn")),
        "w2": PSpec((ff, d), ("ffn", "embed")),
    }


def mlp(p, x, cfg: ArchConfig):
    if cfg.mlp_style == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wu"].astype(x.dtype))
        h = constrain(h, "batch", "seq", "ffn")
        return h @ p["wd"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w1"].astype(x.dtype))
    h = constrain(h, "batch", "seq", "ffn")
    return h @ p["w2"].astype(x.dtype)
