"""repro.models — pure-JAX model substrate for the assigned architectures."""
from .common import (
    PSpec,
    abstract_params,
    constrain,
    init_params,
    param_shardings,
    resolve_spec,
)
from .model import Model, build

__all__ = [
    "Model", "PSpec", "abstract_params", "build", "constrain",
    "init_params", "param_shardings", "resolve_spec",
]
