"""repro.models — pure-JAX model substrate for the assigned architectures."""
from .common import (
    PSpec,
    ShardingProfile,
    abstract_params,
    active_profile,
    constrain,
    init_params,
    param_shardings,
    resolve_profile,
    resolve_spec,
    set_sharding_profile,
    sharding_profile,
)
from .model import Model, build

__all__ = [
    "Model", "PSpec", "ShardingProfile", "abstract_params", "active_profile",
    "build", "constrain", "init_params", "param_shardings", "resolve_profile",
    "resolve_spec", "set_sharding_profile", "sharding_profile",
]
