"""Decoder-LM stack: scanned period-blocks covering dense / MoE / SSM / hybrid
/ VLM families with one code path.

The layer pattern (configs.base.layer_pattern) gives the (sequence-mixer,
channel-mixer) pair per *period position*; parameters are stacked over periods
and the stack runs as one ``lax.scan`` -> HLO size is O(period), not O(layers)
(llama3-405b compiles as a 126-iteration scan; jamba as 4 periods of 8
heterogeneous layers unrolled).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import PSpec, constrain, tree_map_pspec
from .layers import (
    attn_decode,
    attn_prefill,
    attn_specs,
    mlp,
    mlp_specs,
    rmsnorm,
    rmsnorm_spec,
    rope_cos_sin,
)
from .moe import moe, moe_specs
from .ssm import ssd_decode, ssd_prefill, ssm_specs


def stack_specs(tree, n: int):
    return tree_map_pspec(
        lambda _, p: PSpec((n,) + p.shape, ("layers",) + p.logical, p.init), tree
    )


def block_specs(cfg: ArchConfig) -> dict:
    """One period's parameters, keyed pos{i}."""
    out: dict[str, Any] = {}
    for i, (mixer, channel) in enumerate(cfg.layer_pattern()):
        b: dict[str, Any] = {"norm1": rmsnorm_spec(cfg.d_model)}
        if mixer == "attn":
            b["attn"] = attn_specs(cfg)
        else:
            b["ssm"] = ssm_specs(cfg)
        if channel != "none":
            b["norm2"] = rmsnorm_spec(cfg.d_model)
            b["mlp" if channel == "mlp" else "moe"] = (
                mlp_specs(cfg) if channel == "mlp" else moe_specs(cfg)
            )
        out[f"pos{i}"] = b
    return out


def model_specs(cfg: ArchConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab
    specs: dict[str, Any] = {
        "embed": PSpec((V, d), ("vocab", "embed_d"), init="embed"),
        "final_norm": rmsnorm_spec(d),
        "blocks": stack_specs(block_specs(cfg), cfg.n_layers // cfg.period),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = PSpec((d, V), ("embed_d", "vocab"))
    return specs


def cache_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """Decode-cache pytree as PSpecs (shared by init-zeros / abstract / shardings).

    Attention caches are (periods, B, S, Hkv, hd) with the sequence axis
    sharded over `model` (decode-SP); SWA caches are bounded by the window.
    SSM caches are O(1) in sequence.
    """
    n_per = cfg.n_layers // cfg.period
    out: dict[str, Any] = {}
    for i, (mixer, _) in enumerate(cfg.layer_pattern()):
        if mixer == "attn":
            sc = min(seq, cfg.window) if cfg.window else seq
            kv = PSpec(
                (n_per, batch, sc, cfg.n_kv_heads, cfg.hd),
                ("layers", "cache_batch", "cache_seq", "heads", "cache_hd"),
                init="zeros", dtype=cfg.compute_dtype,
            )
            out[f"pos{i}"] = {"k": kv, "v": kv}
        else:
            H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            out[f"pos{i}"] = {
                "ssm": PSpec((n_per, batch, H, P, N),
                             ("layers", "cache_batch", "ssm_inner", "none", "none"),
                             init="zeros", dtype="float32"),
                "conv": PSpec((n_per, batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * N),
                              ("layers", "cache_batch", "none", "ssm_inner"),
                              init="zeros", dtype=cfg.compute_dtype),
            }
    return out


# ---------------------------------------------------------------------- forward
def embed_tokens(params, cfg: ArchConfig, tokens=None, embeds=None):
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    return constrain(x, "batch", "seq", None)


def unembed(params, cfg: ArchConfig, x):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    return constrain(logits, "batch", "seq", "vocab")


def _period_fwd(cfg: ArchConfig, pp, x, cos_sin):
    """Full-seq forward through one period; returns (x, aux, cache_updates)."""
    aux = jnp.zeros((), jnp.float32)
    cache_out = {}
    for i, (mixer, channel) in enumerate(cfg.layer_pattern()):
        b = pp[f"pos{i}"]
        h = rmsnorm(b["norm1"], x, cfg.norm_eps)
        if mixer == "attn":
            a, (k, v) = attn_prefill(b["attn"], h, cfg, cos_sin, window=cfg.window)
            cache_out[f"pos{i}"] = {"k": k, "v": v}
        else:
            a, st = ssd_prefill(b["ssm"], h, cfg)
            cache_out[f"pos{i}"] = st
        x = x + a
        if channel != "none":
            h2 = rmsnorm(b["norm2"], x, cfg.norm_eps)
            if channel == "mlp":
                x = x + mlp(b["mlp"], h2, cfg)
            else:
                y, a_loss = moe(b["moe"], h2, cfg)
                x = x + y
                aux = aux + a_loss
        x = constrain(x, "batch", "seq", None)
    return x, aux, cache_out


def forward_full(params, cfg: ArchConfig, *, tokens=None, embeds=None,
                 positions=None, want_cache: bool = False):
    """Training / prefill forward.  Returns (hidden (B,S,D), aux, cache|None)."""
    x = embed_tokens(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    has_attn = any(m == "attn" for m, _ in cfg.layer_pattern())
    cos_sin = None
    if has_attn and cfg.use_rope:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        cos_sin = rope_cos_sin(cfg, positions)

    def body(carry, pp):
        x, aux = carry
        x2, a, cache = _period_fwd(cfg, pp, x, cos_sin)
        return (x2, aux + a), (cache if want_cache else 0)

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    (x, aux), caches = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                    params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, (caches if want_cache else None)


def decode_step(params, cfg: ArchConfig, cache, *, tokens=None, embeds=None,
                pos=None, positions=None):
    """One-token decode.  tokens: (B, 1); pos: scalar int32 (current position).
    Returns (logits (B, 1, V), new_cache)."""
    x = embed_tokens(params, cfg, tokens, embeds)
    B = x.shape[0]
    has_attn = any(m == "attn" for m, _ in cfg.layer_pattern())
    cos_sin = None
    if has_attn and cfg.use_rope:
        if positions is None:
            positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 1))
        cos_sin = rope_cos_sin(cfg, positions)

    def body(x, scanned):
        pp, pc = scanned
        new_pc = {}
        for i, (mixer, channel) in enumerate(cfg.layer_pattern()):
            b = pp[f"pos{i}"]
            h = rmsnorm(b["norm1"], x, cfg.norm_eps)
            if mixer == "attn":
                a, nc = attn_decode(b["attn"], h, cfg, pc[f"pos{i}"], pos,
                                    cos_sin, window=cfg.window)
            else:
                a, nc = ssd_decode(b["ssm"], h, cfg, pc[f"pos{i}"])
            new_pc[f"pos{i}"] = nc
            x = x + a
            if channel != "none":
                h2 = rmsnorm(b["norm2"], x, cfg.norm_eps)
                if channel == "mlp":
                    x = x + mlp(b["mlp"], h2, cfg)
                else:
                    y, _ = moe(b["moe"], h2, cfg)
                    x = x + y
        return x, new_pc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params, cfg, x), new_cache


# ------------------------------------------------------------------------- loss
def xent_loss(params, cfg: ArchConfig, hidden, labels):
    """Chunked softmax cross-entropy: the (B, S, V) logits are never
    materialized; each sequence chunk computes its own fp32 logits inside a
    rematerialized scan step."""
    B, S, D = hidden.shape
    c = min(cfg.loss_chunk, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (S + pad) // c
    hc = jnp.moveaxis(hidden.reshape(B, n, c, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, c), 1, 0)

    @jax.checkpoint
    def step(carry, xs):
        h, l = xs
        logits = unembed(params, cfg, h)                       # (B,c,V) fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1
        )[..., 0]
        valid = (l >= 0).astype(jnp.float32)
        loss = ((lse - gold) * valid).sum()
        return (carry[0] + loss, carry[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)
