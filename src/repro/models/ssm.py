"""Mamba-2 (SSD — state-space duality) block in pure JAX [arXiv:2405.21060].

Chunked SSD: within a chunk the token-mixing is the quadratic dual form
(masked attention-like (Q,Q) tile, MXU-friendly); across chunks the recurrent
state (B, H, P, N) is carried by an associative ``lax.scan`` in fp32.  Decode
is the O(1) recurrent step.  ngroups=1 (B/C shared across heads), depthwise
causal conv on (x, B, C) as in the reference implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import PSpec, constrain
from .layers import rmsnorm


def _dims(cfg: ArchConfig):
    di = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = di + 2 * N
    return di, H, P, N, conv_dim


def ssm_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    di, H, P, N, conv_dim = _dims(cfg)
    return {
        "in_proj": PSpec((d, 2 * di + 2 * N + H), ("embed", "ssm_inner")),
        "conv_w": PSpec((cfg.ssm_conv, conv_dim), ("none", "ssm_inner")),
        "conv_b": PSpec((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": PSpec((H,), ("none",), init="a_log"),
        "d_skip": PSpec((H,), ("none",), init="ones"),
        "dt_bias": PSpec((H,), ("none",), init="dt_bias"),
        "norm": PSpec((di,), ("ssm_inner",), init="ones"),
        "out_proj": PSpec((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(xbc, w, b):
    """Depthwise causal conv along S: xbc (B, S, Cd), w (k, Cd)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + b)


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j < t <= i} x[..., t] (else -inf)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_prefill(p, x, cfg: ArchConfig, init_state=None):
    """x: (B, S, D) -> (y (B, S, D), final_states dict).  S % chunk == 0 or
    S < chunk (single padded chunk)."""
    B, S, D = x.shape
    di, H, P, N, conv_dim = _dims(cfg)
    Q = min(cfg.ssm_chunk, S)
    pad = (-S) % Q
    dt_dtype = x.dtype

    zxbcdt = x @ p["in_proj"].astype(x.dtype)           # (B,S,2di+2N+H)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    conv_tail = xbc[:, -(cfg.ssm_conv - 1):, :]          # decode conv state seed
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    xs, Bc, Cc = jnp.split(xbc, [di, di + N], axis=-1)   # (B,S,di),(B,S,N),(B,S,N)
    xs = constrain(xs, "batch", "seq", "ssm_inner")

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # (B,S,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                        # (H,)

    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    xh = xs.reshape(B, nc, Q, H, P)
    Bh = Bc.reshape(B, nc, Q, N).astype(jnp.float32)
    Ch = Cc.reshape(B, nc, Q, N).astype(jnp.float32)
    dth = dt.reshape(B, nc, Q, H)                                       # fp32
    dA = dth * A                                                        # (B,nc,Q,H)
    dAc = jnp.cumsum(dA, axis=2)                                        # within-chunk

    # ---- intra-chunk (dual/quadratic form) ----
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, 2)))                       # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", Ch, Bh)                      # (B,nc,Q,Q)
    M = scores[:, :, None] * L                                          # (B,nc,H,Q,Q)
    xdt = xh * dth[..., None].astype(xh.dtype)                          # (B,nc,Q,H,P)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M.astype(xh.dtype), xdt)

    # ---- chunk states ----
    decay_to_end = jnp.exp(dAc[:, :, -1:, :] - dAc)                     # (B,nc,Q,H)
    states = jnp.einsum(
        "bcqn,bcqh,bcqhp->bchpn",
        Bh, (dth * decay_to_end).astype(jnp.float32), xh.astype(jnp.float32),
    )                                                                    # (B,nc,H,P,N)

    # ---- inter-chunk recurrence (fp32 scan) ----
    chunk_decay = jnp.exp(dAc[:, :, -1, :])                              # (B,nc,H)
    s0 = (
        init_state["ssm"].astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )

    def step(carry, inp):
        st, dec = inp                                                    # (B,H,P,N),(B,H)
        prev = carry
        return prev * dec[..., None, None] + st, prev

    (final, prevs) = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prevs, 0, 1)                              # (B,nc,H,P,N)

    # ---- inter-chunk output: y_off[i] = C_i . (prev_state * decay_from_start) ----
    decay_in = jnp.exp(dAc)                                              # (B,nc,Q,H)
    y_off = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp", Ch, prev_states, decay_in
    ).astype(xh.dtype)

    y = (y_diag + y_off).reshape(B, Sp, H, P)[:, :S]
    y = y + xs.reshape(B, Sp, H, P)[:, :S] * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    state = {
        "ssm": final.astype(jnp.float32),
        "conv": jnp.pad(
            conv_tail, ((0, 0), (max(0, cfg.ssm_conv - 1 - S), 0), (0, 0))
        ).astype(x.dtype),
    }
    return constrain(out, "batch", "seq", None), state


def ssd_decode(p, x, cfg: ArchConfig, state):
    """One-token recurrent step.  x: (B, 1, D); state: {ssm (B,H,P,N) fp32,
    conv (B, k-1, conv_dim)} -> (y (B,1,D), new state)."""
    B, _, D = x.shape
    di, H, P, N, conv_dim = _dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)

    hist = jnp.concatenate([state["conv"], xbc], axis=1)                 # (B,k,Cd)
    w = p["conv_w"].astype(x.dtype)
    conv = jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"].astype(x.dtype)
    xbc1 = jax.nn.silu(conv)[:, None, :]
    xs, Bc, Cc = jnp.split(xbc1, [di, di + N], axis=-1)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt1 * A)                                               # (B,H)
    xh = xs[:, 0].reshape(B, H, P).astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt1, xh, Bc[:, 0].astype(jnp.float32))
    new_ssm = state["ssm"] * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), new_ssm)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    new_state = {"ssm": new_ssm, "conv": hist[:, 1:]}
    return out, new_state
