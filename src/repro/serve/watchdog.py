"""Plan-derived deadline watchdog for the serving plane.

The paper's mutual-inclusivity claim says the CEFT plan already carries the
*expected finish time* of every task on its mapped engine class.  Until this
module that information was computed and thrown away: a worker that hung,
stalled, or silently dropped its reply blocked ``Router.serve`` forever.
Here the plan becomes an enforcement budget — every dispatch is armed with

    deadline = dispatch_ts + deadline_factor x planned_span

where ``planned_span`` is the dispatch's expected service time under the
current EWMA cost table x straggler slowdowns (the same numbers the plan was
priced with), floor-clamped by ``min_deadline`` so micro-second smoke spans
do not turn timer noise into false alarms.  A caller that knows better —
the router arming from a backward-propagated latest-finish (ISSUE 9,
repro.sched.deadlines) — passes an explicit ``budget=`` to :meth:`arm` and
that budget replaces the flat multiple for the entry's whole ladder.

The watchdog is deliberately policy-free: it tracks in-flight entries, and a
monitor thread (or an explicit :meth:`sweep` call — tests drive this with an
injected clock) reports overdue entries to the ``on_overdue`` callback with a
strike count.  The *router* owns the response ladder (hedge / report /
requeue / mark_lost); this module only decides *when* the plan's promise was
broken.

Invariant (the reason ci.sh greps keep escalation policy out of this file):
**one strike per budget** — after each strike the entry's deadline is pushed
by exactly one more of ITS OWN budget, so a stuck dispatch escalates strike
by strike instead of firing on every poll, and a three-strike ladder always
spans three budgets of wall clock regardless of the poll interval.  Nothing
in this module ever skips a rung or fires twice inside one budget.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable


@dataclasses.dataclass
class InflightEntry:
    """One armed dispatch attempt."""
    seq: int                     # dispatch-attempt sequence id (queue.next_seq)
    payload: object              # opaque to the watchdog (the router's Dispatch)
    engine: int                  # pool worker index the attempt runs on
    on_critical_path: bool
    planned_span: float          # expected service seconds from the plan
    t0: float                    # arm time (watchdog clock)
    deadline: float              # absolute time the plan's budget expires
    budget: float = 0.0          # per-strike push (flat or SLO-propagated)
    strikes: int = 0             # overdue sweeps that have fired on this entry
    hedged: bool = False         # a speculative clone was already sent
    shed: bool = False           # already requeued by a slack-keyed strike


class DeadlineWatchdog:
    """Sweeps in-flight dispatches against their plan-derived deadlines.

    ``on_overdue(entry, now)`` fires once per strike, outside the internal
    lock (handlers take their own locks — the router's, the pool's).  The
    monitor thread (:meth:`start`) polls every ``poll_interval`` seconds;
    deterministic tests skip the thread and call :meth:`sweep` with an
    explicit ``now`` from an injected ``clock``.
    """

    def __init__(self, *, deadline_factor: float = 3.0,
                 min_deadline: float = 0.05, poll_interval: float = 0.01,
                 clock: Callable[[], float] = time.monotonic,
                 on_overdue: Callable | None = None):
        self.deadline_factor = float(deadline_factor)
        self.min_deadline = float(min_deadline)
        self.poll_interval = float(poll_interval)
        self.clock = clock
        self.on_overdue = on_overdue
        self._lock = threading.Lock()
        self._inflight: dict[int, InflightEntry] = {}
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self.stats = {"armed": 0, "completed": 0, "overdue": 0, "sweeps": 0}

    # --------------------------------------------------------------- tracking
    def budget(self, planned_span: float) -> float:
        """The enforcement budget for one planned span, floor-clamped."""
        return max(self.deadline_factor * float(planned_span),
                   self.min_deadline)

    def arm(self, seq: int, payload, *, planned_span: float, engine: int,
            on_critical_path: bool,
            budget: float | None = None) -> InflightEntry:
        """Track one attempt.  ``budget=None`` (historical behaviour) uses
        the flat ``deadline_factor x planned_span``; an explicit budget — the
        router's SLO-propagated latest-finish — replaces it, floor-clamped by
        ``min_deadline``, and drives every later strike push too."""
        now = self.clock()
        b = (self.budget(planned_span) if budget is None
             else max(float(budget), self.min_deadline))
        entry = InflightEntry(
            seq=int(seq), payload=payload, engine=int(engine),
            on_critical_path=bool(on_critical_path),
            planned_span=float(planned_span), t0=now,
            deadline=now + b, budget=b)
        with self._lock:
            self._inflight[entry.seq] = entry
            self.stats["armed"] += 1
        return entry

    def disarm(self, seq: int) -> InflightEntry | None:
        """Completion (or abandonment): stop watching the attempt."""
        with self._lock:
            entry = self._inflight.pop(int(seq), None)
            if entry is not None:
                self.stats["completed"] += 1
        return entry

    def inflight(self) -> int:
        with self._lock:
            return len(self._inflight)

    # --------------------------------------------------------------- sweeping
    def sweep(self, now: float | None = None) -> list[InflightEntry]:
        """Fire one strike on every overdue entry; returns them.

        Each fired entry's deadline is pushed by one more of ITS OWN budget
        (flat or SLO-propagated, whatever it was armed with) before the
        callback runs, so a still-stuck dispatch escalates one strike per
        budget rather than once per poll, and a handler that disarms the
        entry (mark_lost) simply stops the ladder."""
        now = self.clock() if now is None else now
        with self._lock:
            self.stats["sweeps"] += 1
            fired = []
            for entry in self._inflight.values():
                if entry.deadline <= now:
                    entry.strikes += 1
                    entry.deadline = now + (entry.budget if entry.budget > 0.0
                                            else self.budget(entry.planned_span))
                    self.stats["overdue"] += 1
                    fired.append(entry)
        if self.on_overdue is not None:
            for entry in fired:
                self.on_overdue(entry, now)
        return fired

    # ---------------------------------------------------------- monitor thread
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.poll_interval):
                self.sweep()

        self._thread = threading.Thread(
            target=loop, name="deadline-watchdog", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None
