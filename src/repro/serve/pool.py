"""The placement plane: an elastic engine pool.

Everything that knows *where computation lives* sits in this module.  The
Router above plans over processor classes; this pool owns the classes'
physical reality — worker lifecycle (launch, drain, loss), the transport to
each worker, and the *measured* communication plane between workers — and
exposes it as the paper's :class:`~repro.core.machine.Machine` view through
:meth:`EnginePool.machine`.

Two worker backends:

* ``inproc`` (default) — the existing in-process :class:`~repro.serve.engine.Engine`
  (or any object with ``generate(prompts, ServeConfig)``), held directly.
  Keeps tier-1 hermetic and is bit-identical to the pre-pool direct-engine
  Router for a fixed snapshot.
* ``subprocess`` — a worker process speaking a small length-framed
  pickle-over-pipe protocol (``init`` / ``generate`` / ``probe`` / ``ping``
  / ``close``).  The engine is built inside the child from a
  ``"module:callable"`` factory path, so the parent never pickles live
  engines.  A dead pipe surfaces as :class:`WorkerLost`.

Comm-plane measurement: with ``probe="measure"`` (or an injected callable,
for determinism in tests) the pool times a payload transfer leg per worker —
in this architecture KV handoffs between workers are parent-relayed, so the
pair cost a→b is the measured egress leg of a plus the ingress leg of b —
EWMA-smooths the rates, and quantizes them onto a sqrt2 grid so the Machine
snapshot (and hence the plan cache's machine fingerprint) only changes when
a measurement moves materially, not on every probe.  A snapshot change
notifies listeners, which feed ``sched/plancache`` invalidation.  With
``probe="static"`` the plane is the fixed proxy (PR 5's
``router_machine``), byte-stable forever.

Failure as degradation: a lost worker KEEPS its slot (its processor-class
column).  Listeners (the Router) mark the column fully degraded in the
StragglerMonitor, and the existing batched nominal+degraded re-plan routes
the critical path around it — failover needs no new planner code.  Launching
into a freed slot revives the column.

Worker lifecycle state (``_WorkerState``, the subprocess protocol, the
worker bootstrap) is private to this module; ``scripts/ci.sh`` greps that it
stays that way.
"""
from __future__ import annotations

import dataclasses
import importlib
import os
import pickle
import struct
import subprocess
import sys
import threading
import time
from pathlib import Path
from typing import Callable, Sequence

import numpy as np

from ..core.machine import Machine
from ..substrate import process_topology


class _WorkerState:
    """Lifecycle states, private to the pool (ci.sh greps for leaks)."""
    LIVE = "live"
    DRAINED = "drained"
    LOST = "lost"


class WorkerLost(RuntimeError):
    """A worker died (process exit, broken pipe, a corrupt protocol frame, or
    an injected loss).

    Carries per-engine context so serve-loop error handling can report which
    pool member failed without string-parsing."""

    def __init__(self, name: str, index: int, cause: str = "worker lost"):
        super().__init__(f"{name} (engine {index}): {cause}")
        self.engine_name = name
        self.index = index
        self.cause = cause


class FrameError(RuntimeError):
    """The length-framed pickle stream is corrupt (bad header, truncated
    body, garbage payload bytes).  The transport cannot resynchronize a
    corrupt stream, so the worker layer converts this to :class:`WorkerLost`
    with per-engine context — never a hang, never a raw ``EOFError``."""


@dataclasses.dataclass
class EngineSlot:
    """One pool member as the Router sees it: anything with
    ``generate(prompts, ServeConfig)``, pinned to a sharding profile."""
    name: str
    engine: object
    profile: str


@dataclasses.dataclass
class WorkerSpec:
    """How to (re)create one worker.  ``engine`` holds a live object for
    inproc workers; ``factory`` is a ``"module:callable"`` path built inside
    the child for subprocess workers (the parent never pickles engines)."""
    name: str
    profile: str = "baseline"
    engine: object = None
    factory: str | None = None
    args: tuple = ()
    kwargs: dict = dataclasses.field(default_factory=dict)
    backend: str = "inproc"


def null_engine_factory():
    """Cheapest poolable engine: zero tokens, numpy only (tests/benches)."""
    class _Null:
        def generate(self, prompts, scfg):
            B, P = np.asarray(prompts).shape
            return np.zeros((B, P + scfg.max_new_tokens), np.int32)
    return _Null()


def smoke_engine_factory(arch: str, profile: str):
    """A real smoke-scale Engine for subprocess workers (built in the child)."""
    from .. import configs as C
    from .engine import Engine
    return Engine(C.get(arch, smoke=True), profile=profile)


# ----------------------------------------------------------------- transport
# Sanity cap on one frame: a corrupt header decodes to a random 64-bit
# length; without the cap the reader blocks trying to consume exabytes (a
# hang), with it the garbage surfaces immediately as FrameError.
_MAX_FRAME = 1 << 31


def _send_msg(fobj, obj) -> None:
    b = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    fobj.write(struct.pack("<Q", len(b)))
    fobj.write(b)
    fobj.flush()


def _recv_msg(fobj):
    hdr = fobj.read(8)
    if len(hdr) < 8:
        raise EOFError("pipe closed")
    (n,) = struct.unpack("<Q", hdr)
    if n > _MAX_FRAME:
        raise FrameError(f"frame length {n} exceeds cap (corrupt header)")
    b = fobj.read(n)
    if len(b) < n:
        raise EOFError(f"pipe closed mid-message (truncated frame: "
                       f"{len(b)}/{n} bytes)")
    try:
        return pickle.loads(b)
    except BaseException as e:
        raise FrameError(
            f"corrupt frame payload: {type(e).__name__}: {e}") from e


def _worker_main() -> None:  # pragma: no cover - runs in the child process
    """Subprocess worker loop: framed pickle requests on stdin, replies on
    the ORIGINAL stdout (sys.stdout is re-pointed at stderr first, so engine
    prints cannot corrupt the protocol stream).

    Every request carries a monotonic sequence id and every reply echoes it:
    the parent matches replies to requests by seq, so a duplicated reply
    frame (a retransmitting transport, an injected duplicate-reply fault) is
    dropped as stale instead of desynchronizing the stream.  A corrupt
    inbound frame is unrecoverable (the stream cannot resync), so the worker
    exits and the parent sees the EOF as :class:`WorkerLost`."""
    out = sys.stdout.buffer
    sys.stdout = sys.stderr
    inp = sys.stdin.buffer
    engine = None
    while True:
        try:
            msg = _recv_msg(inp)
        except (EOFError, FrameError):
            return
        seq, op, rest = msg[0], msg[1], msg[2:]
        try:
            if op == "init":
                path, args, kwargs = rest
                mod, _, fn = path.partition(":")
                engine = getattr(importlib.import_module(mod), fn)(*args, **kwargs)
                _send_msg(out, (seq, "ok", process_topology()))
            elif op == "generate":
                prompts, max_new, eos = rest
                from .engine import ServeConfig
                toks = engine.generate(
                    prompts, ServeConfig(max_new_tokens=max_new, eos_id=eos))
                _send_msg(out, (seq, "ok", np.asarray(toks)))
            elif op == "probe":
                (payload,) = rest
                _send_msg(out, (seq, "ok", len(payload)))
            elif op == "ping":
                _send_msg(out, (seq, "ok", "pong"))
            elif op == "close":
                _send_msg(out, (seq, "ok", None))
                return
            else:
                _send_msg(out, (seq, "err", f"unknown op {op!r}", ""))
        except BaseException as e:  # reply, don't die: the parent decides
            import traceback
            _send_msg(out, (seq, "err", f"{type(e).__name__}: {e}",
                            traceback.format_exc()))


_CHILD_BOOT = "from repro.serve.pool import _worker_main; _worker_main()"


class _InprocWorker:
    """Backend for engines living in this process (the historical reality)."""
    kind = "inproc"

    def __init__(self, spec: WorkerSpec):
        if spec.engine is not None:
            self.engine = spec.engine
        else:
            mod, _, fn = spec.factory.partition(":")
            self.engine = getattr(importlib.import_module(mod), fn)(
                *spec.args, **spec.kwargs)
        self.topology = process_topology()

    def generate(self, prompts, scfg):
        return self.engine.generate(prompts, scfg)

    def probe(self, payload: bytes) -> None:
        # the local transfer leg really is a serialize/deserialize round:
        # that is what a same-process KV handoff costs on this transport
        pickle.loads(pickle.dumps(payload))

    def ping(self) -> None:
        pass

    def close(self) -> None:
        pass


class _SubprocWorker:
    """Backend for a worker process on this host, one pipe pair per worker.

    Requests carry monotonic sequence ids; :meth:`_reply_for` matches replies
    by seq, dropping stale (duplicated / late) reply frames into
    ``stats["stale_replies"]`` instead of letting them desynchronize the
    stream, and surfacing truncated or corrupt frames as :class:`WorkerLost`
    with per-engine context."""
    kind = "subprocess"

    close_timeout = 5.0   # graceful-exit grace before SIGKILL

    def __init__(self, spec: WorkerSpec, *, index: int, env: dict | None = None,
                 stats: dict | None = None):
        if not spec.factory:
            raise ValueError(f"subprocess worker {spec.name!r} needs a "
                             "'module:callable' factory path")
        self._name, self._index = spec.name, index
        self.stats = stats if stats is not None else {"stale_replies": 0}
        child_env = dict(os.environ)
        src = str(Path(__file__).resolve().parents[2])
        pp = child_env.get("PYTHONPATH", "")
        child_env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
        child_env.update(env or {})
        self.proc = subprocess.Popen(
            [sys.executable, "-c", _CHILD_BOOT], stdin=subprocess.PIPE,
            stdout=subprocess.PIPE, env=child_env)
        self._lock = threading.Lock()
        self._seq = 0
        self.topology = self._rpc(
            ("init", spec.factory, spec.args, spec.kwargs))

    def _reply_for(self, seq: int):
        """Read replies until the one matching ``seq``: a LOWER seq is a
        stale frame (duplicated or late reply) — dropped and counted — while
        a higher seq means the stream skipped a reply and cannot be trusted."""
        while True:
            reply = _recv_msg(self.proc.stdout)
            if not isinstance(reply, tuple) or len(reply) < 2:
                raise FrameError(f"malformed reply {type(reply).__name__}")
            if reply[0] == seq:
                return reply
            if isinstance(reply[0], int) and reply[0] < seq:
                self.stats["stale_replies"] = \
                    self.stats.get("stale_replies", 0) + 1
                continue
            raise FrameError(
                f"protocol desync: got reply seq {reply[0]!r}, want {seq}")

    def _rpc(self, msg):
        with self._lock:
            try:
                self._seq += 1
                seq = self._seq
                _send_msg(self.proc.stdin, (seq,) + msg)
                reply = self._reply_for(seq)
            except (EOFError, BrokenPipeError, OSError, FrameError) as e:
                raise WorkerLost(self._name, self._index,
                                 f"pipe to worker died ({e})") from e
        if reply[1] == "ok":
            return reply[2]
        raise RuntimeError(
            f"worker {self._name} failed: {reply[2]}\n{reply[3]}")

    def generate(self, prompts, scfg):
        return self._rpc(("generate", np.asarray(prompts),
                          int(scfg.max_new_tokens), int(scfg.eos_id)))

    def probe(self, payload: bytes) -> None:
        self._rpc(("probe", payload))

    def ping(self) -> None:
        self._rpc(("ping",))

    def close(self) -> None:
        """Shut the worker down WITHOUT ever blocking forever or leaking:
        polite close rpc only if the pipe is free (a generate blocked on a
        hung child holds the lock — trying to rpc under it would deadlock),
        then wait → SIGKILL → reap, then close both pipe fds.  A hung or
        SIGSTOP'd child cannot leave a zombie or leaked fds behind across
        drain + relaunch cycles."""
        if self._lock.acquire(blocking=False):
            try:
                self._seq += 1
                # fire-and-forget: NEVER read the reply here — a stopped or
                # hung child would block the read forever, and proc.wait()
                # below observes the graceful exit anyway
                _send_msg(self.proc.stdin, (self._seq, "close"))
            except (BrokenPipeError, OSError, ValueError):
                pass
            finally:
                self._lock.release()
        try:
            self.proc.wait(timeout=self.close_timeout)
        except subprocess.TimeoutExpired:
            self.proc.kill()          # SIGKILL stops even a SIGSTOP'd child
            self.proc.wait()          # reap: no zombie survives close()
        for fobj in (self.proc.stdin, self.proc.stdout):
            try:
                fobj.close()
            except Exception:
                pass


@dataclasses.dataclass
class _PoolMember:
    spec: WorkerSpec
    handle: object
    state: str = _WorkerState.LIVE


def _quantize_rate(x: np.ndarray) -> np.ndarray:
    """Snap measured rates onto a sqrt2 geometric grid: the Machine snapshot
    (and the plan cache's machine fingerprint) must only move when a
    measurement moves materially, not on every probe's timer noise."""
    x = np.asarray(x, np.float64)
    return np.exp2(np.round(np.log2(np.maximum(x, 1e-30)) * 2.0) / 2.0)


class EnginePool:
    """Owns worker lifecycle and the measured communication plane.

    ``specs`` seed the pool; ``probe`` selects the comm plane: ``"static"``
    (fixed proxy, byte-stable — the compat default for
    :meth:`from_slots`), ``"measure"`` (real transfer probes), or a callable
    ``(member, payload) -> seconds`` measuring one transfer leg (tests
    inject deterministic clocks here).  ``autoscale`` enables queue-depth
    driven :meth:`maybe_autoscale` between ``min_size`` and ``max_size``.

    Listeners receive ``fn(event, payload)`` with events ``"lost"`` /
    ``"launch"`` / ``"drain"`` (payload = worker index) and ``"machine"``
    (payload = the previous Machine snapshot).
    """

    def __init__(self, specs: Sequence[WorkerSpec] = (), *,
                 backend: str = "inproc",
                 probe: str | Callable = "static",
                 kv_bw: float = 1e4, latency: float = 1e-3,
                 probe_tokens: int = 4096, bw_alpha: float = 0.3,
                 min_size: int = 1, max_size: int | None = None,
                 autoscale: bool = False,
                 high_water: int = 8, low_water: int = 0,
                 machine: Machine | None = None,
                 child_env: dict | None = None,
                 relaunch_budget: int = 3,
                 relaunch_backoff: float = 0.5,
                 relaunch_backoff_max: float = 30.0):
        self.backend = backend
        self.probe = probe
        self.kv_bw = float(kv_bw)
        self.latency = float(latency)
        self.probe_tokens = int(probe_tokens)
        self.bw_alpha = float(bw_alpha)
        self.min_size = int(min_size)
        self.max_size = max_size if max_size is None else int(max_size)
        self.autoscale = bool(autoscale)
        self.high_water = int(high_water)
        self.low_water = int(low_water)
        self.child_env = child_env
        self.relaunch_budget = int(relaunch_budget)
        self.relaunch_backoff = float(relaunch_backoff)
        self.relaunch_backoff_max = float(relaunch_backoff_max)
        self._members: list[_PoolMember] = []
        self._listeners: list[Callable] = []
        self._handle_wrappers: list[Callable] = []
        self._lat_ewma: np.ndarray = np.zeros(0)      # seconds, ping round-trip
        self._leg_ewma: np.ndarray = np.zeros(0)      # tokens/s, transfer leg
        self._machine: Machine | None = None
        self._pinned_machine = machine
        self._autoscaled: list[int] = []
        self._relaunch_attempts: dict[int, int] = {}
        self._relaunch_next: dict[int, float] = {}
        self.stats = {"launched": 0, "drained": 0, "lost": 0, "probes": 0,
                      "scale_out": 0, "scale_in": 0, "stale_replies": 0,
                      "relaunches": 0, "relaunch_exhausted": 0}
        for spec in specs:
            self.launch(spec)

    # ------------------------------------------------------------ construction
    @classmethod
    def from_slots(cls, slots: Sequence[EngineSlot], *,
                   machine: Machine | None = None, **kw) -> "EnginePool":
        """Wrap a direct engine list (the pre-pool Router input) as an
        in-process pool with the byte-stable static comm plane — plans for a
        fixed snapshot are bit-identical to the direct-engine Router."""
        specs = [WorkerSpec(s.name, s.profile, engine=s.engine) for s in slots]
        return cls(specs, probe=kw.pop("probe", "static"), machine=machine, **kw)

    # ----------------------------------------------------------------- views
    @property
    def size(self) -> int:
        """Processor-class count: lost/drained workers KEEP their column."""
        return len(self._members)

    def live_indices(self) -> list[int]:
        return [i for i, m in enumerate(self._members)
                if m.state == _WorkerState.LIVE]

    def state(self, idx: int) -> str:
        return self._members[idx].state

    @property
    def slots(self) -> list[EngineSlot]:
        """The Router/test-facing view; inproc members expose their engine
        object, subprocess members their handle."""
        return [EngineSlot(m.spec.name,
                           getattr(m.handle, "engine", m.handle),
                           m.spec.profile)
                for m in self._members]

    def worker_pid(self, idx: int) -> int | None:
        """OS pid of a subprocess worker (None for inproc) — lets tests and
        operators kill a real worker from outside the pool's own API."""
        proc = getattr(self._members[idx].handle, "proc", None)
        return None if proc is None else proc.pid

    def topology(self) -> list[dict | None]:
        """Per-worker host/process placement, as reported through the
        substrate seam (subprocess workers report their own child's view)."""
        return [getattr(m.handle, "topology", None) for m in self._members]

    def add_listener(self, fn: Callable) -> None:
        self._listeners.append(fn)

    def add_handle_wrapper(self, wrap: Callable) -> None:
        """Public middleware seam for the worker transport: every current and
        future handle is replaced by ``wrap(index, handle)``.  The wrapper
        must expose the handle protocol (generate/probe/ping/close).  This is
        how tracing or fault injection (``repro.serve.faults``) attaches
        without touching the pool's private lifecycle state."""
        self._handle_wrappers.append(wrap)
        for i, m in enumerate(self._members):
            m.handle = wrap(i, m.handle)

    def _notify(self, event: str, payload) -> None:
        for fn in self._listeners:
            fn(event, payload)

    # ------------------------------------------------------------- lifecycle
    def _build_handle(self, spec: WorkerSpec, idx: int):
        backend = spec.backend or self.backend
        if backend == "subprocess":
            handle = _SubprocWorker(spec, index=idx, env=self.child_env,
                                    stats=self.stats)
        elif backend == "inproc":
            handle = _InprocWorker(spec)
        else:
            raise ValueError(f"unknown pool backend {backend!r}")
        for wrap in self._handle_wrappers:
            handle = wrap(idx, handle)
        return handle

    def launch(self, spec: WorkerSpec, idx: int | None = None) -> int:
        """Start a worker.  Freed slots (lost/drained) are revived in place so
        processor-class columns stay index-stable; otherwise a new column is
        appended.  ``idx`` targets a specific freed slot (the relaunch path);
        by default the first freed slot is revived.  Returns the worker
        index."""
        if not spec.backend:
            spec = dataclasses.replace(spec, backend=self.backend)
        freed = [i for i, m in enumerate(self._members)
                 if m.state != _WorkerState.LIVE]
        if idx is not None:
            if self._members[idx].state == _WorkerState.LIVE:
                raise ValueError(f"slot {idx} is live; drain it first")
            self._members[idx] = _PoolMember(spec, self._build_handle(spec, idx))
        elif freed:
            idx = freed[0]
            self._members[idx] = _PoolMember(spec, self._build_handle(spec, idx))
        else:
            idx = len(self._members)
            self._members.append(_PoolMember(spec, self._build_handle(spec, idx)))
            self._lat_ewma = np.concatenate([self._lat_ewma, [np.nan]])
            self._leg_ewma = np.concatenate([self._leg_ewma, [np.nan]])
        # a revived column's old measurements belong to the previous worker
        self._lat_ewma[idx] = np.nan
        self._leg_ewma[idx] = np.nan
        self.stats["launched"] += 1
        self._notify("launch", idx)
        return idx

    def drain(self, idx: int) -> None:
        """Gracefully retire a worker: close the handle, keep the column."""
        m = self._members[idx]
        if m.state != _WorkerState.LIVE:
            return
        m.state = _WorkerState.DRAINED
        try:
            m.handle.close()
        except Exception:
            pass
        self.stats["drained"] += 1
        self._notify("drain", idx)

    def mark_lost(self, idx: int, cause: str = "worker lost") -> None:
        """Record a worker death.  The column stays: listeners degrade it
        (StragglerMonitor) and the nominal+degraded re-plan routes around it."""
        m = self._members[idx]
        if m.state == _WorkerState.LOST:
            return
        m.state = _WorkerState.LOST
        try:
            m.handle.close()
        except Exception:
            pass
        self.stats["lost"] += 1
        self._notify("lost", idx)

    def close(self) -> None:
        for i in self.live_indices():
            self.drain(i)

    # -------------------------------------------------------------- relaunch
    def relaunchable(self) -> list[int]:
        """Lost slots still inside their relaunch budget."""
        return [i for i, m in enumerate(self._members)
                if m.state == _WorkerState.LOST
                and self._relaunch_attempts.get(i, 0) < self.relaunch_budget]

    def maybe_relaunch(self, idx: int, now: float | None = None) -> bool:
        """Try to revive one lost slot from its own spec, under a bounded
        exponential backoff and a hard per-slot attempt budget: a
        crash-looping worker costs at most ``relaunch_budget`` relaunches,
        then converges to permanently-degraded (its column stays LOST, the
        degraded re-plan keeps routing around it) instead of flapping the
        machine fingerprint on every crash cycle."""
        m = self._members[idx]
        if m.state != _WorkerState.LOST:
            return False
        attempts = self._relaunch_attempts.get(idx, 0)
        if attempts >= self.relaunch_budget:
            return False
        now = time.monotonic() if now is None else now
        if now < self._relaunch_next.get(idx, 0.0):
            return False
        self._relaunch_attempts[idx] = attempts + 1
        self._relaunch_next[idx] = now + min(
            self.relaunch_backoff * (2.0 ** attempts),
            self.relaunch_backoff_max)
        if self._relaunch_attempts[idx] >= self.relaunch_budget:
            self.stats["relaunch_exhausted"] += 1
        try:
            self.launch(dataclasses.replace(m.spec), idx=idx)
        except Exception:
            # the relaunch itself crashed (factory raised, spawn failed):
            # that consumed one budgeted attempt; the slot stays lost
            self._members[idx].state = _WorkerState.LOST
            return False
        self.stats["relaunches"] += 1
        return True

    def maybe_relaunch_lost(self, now: float | None = None) -> list[int]:
        """Attempt every budget-eligible lost slot; returns revived indices."""
        return [i for i in self.relaunchable() if self.maybe_relaunch(i, now)]

    # -------------------------------------------------------------- dispatch
    def generate(self, idx: int, prompts, scfg):
        """Run one micro-batch on worker ``idx``; :class:`WorkerLost` (from a
        dead pipe or the engine itself) marks the worker lost before
        re-raising, so the caller's very next plan sees the degraded column."""
        m = self._members[idx]
        if m.state != _WorkerState.LIVE:
            raise WorkerLost(m.spec.name, idx, f"worker is {m.state}")
        try:
            return m.handle.generate(prompts, scfg)
        except WorkerLost as e:
            self.mark_lost(idx, e.cause)
            raise
        except (BrokenPipeError, EOFError) as e:
            self.mark_lost(idx, str(e))
            raise WorkerLost(m.spec.name, idx, str(e)) from e

    # ------------------------------------------------------------ comm plane
    def _measure_leg(self, member: _PoolMember, payload: bytes) -> float:
        t0 = time.perf_counter()
        member.handle.probe(payload)
        return time.perf_counter() - t0

    def refresh_probes(self) -> None:
        """Measure one transfer leg + dispatch latency per live worker and
        EWMA-fold them into the comm plane.  No-op for the static proxy."""
        if self.probe == "static":
            return
        injected = callable(self.probe)
        leg = self.probe if injected else self._measure_leg
        payload = b"\x00" * (self.probe_tokens * 4)   # int32 tokens
        a = self.bw_alpha
        for i in self.live_indices():
            m = self._members[i]
            sec = max(float(leg(m, payload)), 1e-9)
            rate = self.probe_tokens / sec
            self.stats["probes"] += 1
            old_r = self._leg_ewma[i]
            self._leg_ewma[i] = (rate if np.isnan(old_r)
                                 else a * rate + (1 - a) * old_r)
            if injected:
                # an injected clock covers the transfer leg only; latency
                # stays at the configured default so tests are deterministic
                continue
            t0 = time.perf_counter()
            m.handle.ping()
            lat = max(time.perf_counter() - t0, 1e-9)
            old_l = self._lat_ewma[i]
            self._lat_ewma[i] = (lat if np.isnan(old_l)
                                 else a * lat + (1 - a) * old_l)

    def machine(self) -> Machine:
        """The pool as a CEFT machine: one class per worker (count 1).  The
        returned object is a cached SNAPSHOT — it is replaced (and listeners
        notified with the old snapshot, for plan-cache invalidation) only
        when quantized measurements or the pool shape actually change."""
        if self._pinned_machine is not None:
            return self._pinned_machine
        P = max(self.size, 1)
        L = np.full(P, self.latency, np.float64)
        bw = np.full((P, P), self.kv_bw, np.float64)
        if self.probe != "static" and self._leg_ewma.size:
            lq = _quantize_rate(self._lat_ewma[:P])
            L = np.where(np.isnan(self._lat_ewma[:P]), L, lq)
            # pair rate a->b composes the measured legs (the handoff is
            # parent-relayed: egress from a, then ingress into b), then
            # snaps onto the sqrt2 grid so the fingerprint stays put under
            # probe timer noise
            legs = self._leg_ewma[:P]
            with np.errstate(invalid="ignore"):
                pair = 1.0 / (1.0 / legs[:, None] + 1.0 / legs[None, :])
            pq = _quantize_rate(pair)
            ok = ~np.isnan(legs[:, None]) & ~np.isnan(legs[None, :])
            bw = np.where(ok, pq, bw)
        m = self._machine
        if (m is not None and m.P == P and np.array_equal(m.L, L)
                and np.array_equal(m.bw, bw)):
            return m
        self._machine = Machine(L=L, bw=bw, counts=np.ones(P, np.int64))
        if m is not None:
            self._notify("machine", m)
        return self._machine

    # -------------------------------------------------------------- autoscale
    def maybe_autoscale(self, depth: int) -> str | None:
        """Queue-depth policy: scale OUT (clone the first worker's spec) when
        the backlog per live worker exceeds ``high_water`` and the pool is
        below ``max_size``; DRAIN the most recent autoscaled worker when the
        backlog falls to ``low_water`` or below.  Returns "out"/"in"/None."""
        if not self.autoscale:
            return None
        live = self.live_indices()
        if not live:
            return None
        if depth > self.high_water * len(live) and (
                self.max_size is None or len(live) < self.max_size):
            base = self._members[live[0]].spec
            idx = self.launch(dataclasses.replace(
                base, name=f"{base.name}~{self.stats['launched']}"))
            self._autoscaled.append(idx)
            self.stats["scale_out"] += 1
            return "out"
        if depth <= self.low_water and len(live) > self.min_size \
                and self._autoscaled:
            idx = self._autoscaled.pop()
            if self._members[idx].state == _WorkerState.LIVE:
                self.drain(idx)
                self.stats["scale_in"] += 1
                return "in"
        return None
