"""Batched serving engine: prefill once, decode greedily with per-sequence
EOS stop, KV cache reconciliation between the prefill and decode layouts
(including SWA ring-buffer packing)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.common import (
    ShardingProfile,
    active_profile,
    init_params,
    resolve_profile,
    sharding_profile,
)
from ..models.model import Model, build


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    eos_id: int = 1


class Engine:
    def __init__(self, cfg: ArchConfig, params=None, seed: int = 0,
                 profile: str | ShardingProfile | None = None):
        self.cfg = cfg
        # Pin the sharding profile at construction (default: whatever is
        # active right now).  Every trace -- init here, prefill/decode in
        # generate() -- re-enters it, so two engines with different profiles
        # in one process each resolve their own rules, never each other's.
        self.profile = (resolve_profile(profile) if profile is not None
                        else active_profile())
        self.model = build(cfg)
        with sharding_profile(self.profile):
            self.params = params if params is not None else self.model.init(
                jax.random.PRNGKey(seed))
        self._decode = jax.jit(self.model.decode)
        self._prefill = jax.jit(self.model.prefill)

    # ------------------------------------------------------------------ cache
    def _seed_cache(self, prefill_cache, B: int, total: int, prompt: int):
        """Pack the prefill K/V (length=prompt) into the decode layout
        (length=total or window); SSM states pass through unchanged."""
        cfg = self.cfg
        target = init_params(self.model.cache_specs(B, total), jax.random.PRNGKey(0))

        def pack(dst, src, window):
            # src: (periods, B, prompt, H, hd) -> dst: (periods, B, Sc, H, hd)
            if window and prompt >= window:
                tail = src[:, :, prompt - window:]
                # ring layout: slot(t) = t % window for t in [prompt-window, prompt)
                idx = (np.arange(prompt - window, prompt) % window)
                return dst.at[:, :, idx].set(tail.astype(dst.dtype))
            s = min(prompt, dst.shape[2])
            return dst.at[:, :, :s].set(src[:, :, :s].astype(dst.dtype))

        out = {}
        for k, sub in target.items():
            if "k" in sub:  # attention cache
                w = min(total, cfg.window) if cfg.window else 0
                out[k] = {n: pack(sub[n], prefill_cache[k][n], w) for n in ("k", "v")}
            else:           # ssm state: copy as-is
                out[k] = {n: prefill_cache[k][n].astype(sub[n].dtype) for n in sub}
        return out

    # --------------------------------------------------------------- generate
    def generate(self, prompts: np.ndarray, scfg: ServeConfig | None = None):
        """prompts: (B, P) int32.  Returns (B, P+new) tokens (greedy)."""
        with sharding_profile(self.profile):
            return self._generate(prompts, scfg)

    def _generate(self, prompts: np.ndarray, scfg: ServeConfig | None = None):
        scfg = scfg or ServeConfig()
        cfg = self.cfg
        B, P = prompts.shape
        total = P + scfg.max_new_tokens
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model), jnp.float32)
        pf_cache, logits = self._prefill(self.params, batch)
        if cfg.family == "encdec":
            cache = {"self": self._seed_cache(
                {"pos0": pf_cache["self"]}, B, total, P)["pos0"],
                "cross": pf_cache["cross"]}
        else:
            cache = self._seed_cache(pf_cache, B, total, P)

        toks = np.zeros((B, total), np.int32)
        toks[:, :P] = prompts
        done = np.zeros(B, bool)
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for t in range(P, total):
            toks[:, t] = np.where(done, scfg.eos_id, np.asarray(cur))
            done |= toks[:, t] == scfg.eos_id
            if done.all() or t == total - 1:
                break
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(toks[:, t:t + 1]), jnp.int32(t))
            cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return toks
