"""Deterministic fault injection for the serving plane (chaos harness).

Invariant: **faults enter only through the wrapper seam**.  Everything that
can *break* a pool worker on purpose lives here — this module is the only
place allowed to attach to :meth:`EnginePool.add_handle_wrapper`
(``scripts/ci.sh`` greps that the hook stays private to it), so production
code paths contain zero fault branches: disarmed, the pool runs the exact
bytes a chaos run exercises, and a fault can never hide in router/pool
logic where it would fire outside a chaos soak.  The injector
wraps every worker handle (both backends: inproc and subprocess) with a proxy
that consults a :class:`FaultPlan` — a scripted or seed-derived schedule of
faults keyed by (worker index, per-worker generate-call number) — and fails
the call the way real infrastructure fails:

=============  ==============================================================
``delay``      sleep before forwarding (a transient stall, below loss)
``hang``       block until released — the unreachable-worker case the
               deadline watchdog exists for; released hangs surface as
               :class:`WorkerLost`
``kill``       SIGKILL the subprocess child mid-call (inproc: synthesize the
               resulting :class:`WorkerLost`), so the parent sees a dead pipe
``drop``       run the work, drop the reply, surface :class:`WorkerLost` —
               the request executed but the caller can never know
``corrupt``    write garbage bytes into the protocol stream (subprocess: the
               real framing layer must convert the desync to
               :class:`WorkerLost`; inproc: synthesized)
``dup``        run the work but HOLD the reply past the deadline budget and
               return it late — the duplicate-reply case: a hedge wins the
               race and the late original must be dropped by rid dedup
               (``stats["stale_replies"]``), never double-completed
=============  ==============================================================

Determinism: :meth:`FaultPlan.seeded` derives the whole schedule from one
integer seed via ``random.Random`` — the same seed replays the same faults at
the same call numbers on the same workers, which is what lets ci.sh run a
chaos soak as a *smoke test* instead of a flake generator.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time

from .pool import EnginePool, WorkerLost

# Fault kinds the injector understands (see module docstring table).
KINDS = ("delay", "hang", "kill", "drop", "corrupt", "dup")


@dataclasses.dataclass
class Fault:
    """One scheduled fault: fires on worker ``worker``'s ``call``-th
    generate() (1-based, counted per worker)."""
    worker: int
    call: int
    kind: str
    param: float = 0.0     # delay/dup hold seconds; unused otherwise

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(choose from {KINDS})")


class FaultPlan:
    """A schedule of :class:`Fault`\\ s, scripted or seed-derived."""

    def __init__(self, faults: list[Fault] | None = None):
        self._by_slot: dict[tuple[int, int], Fault] = {}
        for f in faults or []:
            self.add(f.worker, f.call, f.kind, f.param)

    def add(self, worker: int, call: int, kind: str,
            param: float = 0.0) -> "FaultPlan":
        self._by_slot[(int(worker), int(call))] = Fault(
            int(worker), int(call), kind, float(param))
        return self

    def pop(self, worker: int, call: int) -> Fault | None:
        return self._by_slot.pop((int(worker), int(call)), None)

    def __len__(self) -> int:
        return len(self._by_slot)

    @classmethod
    def seeded(cls, seed: int, workers: int, *, calls: int = 10,
               rate: float = 0.25, kinds: tuple = KINDS,
               delay: float = 0.05, hold: float = 0.5) -> "FaultPlan":
        """Derive a full schedule from one integer seed: each of the first
        ``calls`` generate() calls on each worker independently draws a fault
        with probability ``rate``.  Worker 0 is exempted from ``kill`` and
        ``hang`` on its first call so a seeded soak can never open by losing
        every worker before any request completes (the soak asserts
        exactly-once, not survival-of-zero-workers)."""
        rng = random.Random(int(seed))
        plan = cls()
        for w in range(int(workers)):
            for c in range(1, int(calls) + 1):
                if rng.random() >= rate:
                    continue
                kind = rng.choice(list(kinds))
                if w == 0 and c == 1 and kind in ("kill", "hang"):
                    kind = "delay"
                param = delay if kind == "delay" else hold
                plan.add(w, c, kind, param)
        return plan


class FaultInjector:
    """Installs a :class:`FaultPlan` on a pool via the public handle-wrapper
    seam; owns the hang-release latch and the per-kind fired counters."""

    def __init__(self, plan: FaultPlan, *, hang_timeout: float = 60.0):
        self.plan = plan
        self.hang_timeout = float(hang_timeout)
        self.stats = {k: 0 for k in KINDS}
        self.stats["calls"] = 0
        self._lock = threading.Lock()
        self._calls: dict[int, int] = {}
        self._release = threading.Event()

    def install(self, pool: EnginePool) -> "FaultInjector":
        pool.add_handle_wrapper(self._wrap)
        return self

    def release(self) -> None:
        """Release every in-flight injected hang (they surface as
        :class:`WorkerLost`).  Idempotent.  Deliberately NOT fired by handle
        close(): mark_lost closes handles, and a kill on one worker must not
        cut every other worker's hang short — ``hang_timeout`` bounds the
        abandoned threads instead."""
        self._release.set()

    # ----------------------------------------------------------- wrapping
    def _wrap(self, idx: int, handle):
        return _FaultyHandle(self, idx, handle)

    def _next_call(self, idx: int) -> int:
        with self._lock:
            n = self._calls.get(idx, 0) + 1
            self._calls[idx] = n
            self.stats["calls"] += 1
            return n


class _FaultyHandle:
    """Worker-handle proxy: forwards the handle protocol, injecting the
    plan's fault (if any) for each generate() call.  Private to this module —
    production code never sees fault machinery."""

    def __init__(self, injector: FaultInjector, idx: int, inner):
        self._injector = injector
        self._idx = idx
        self._inner = inner
        self._name = getattr(inner, "_name", f"engine{idx}")

    # anything else the pool reads off a handle (engine, proc, topology)
    # passes straight through, so pool.slots / worker_pid keep working
    def __getattr__(self, attr):
        return getattr(self._inner, attr)

    def generate(self, prompts, scfg):
        inj = self._injector
        fault = inj.plan.pop(self._idx, inj._next_call(self._idx))
        if fault is None:
            return self._inner.generate(prompts, scfg)
        inj.stats[fault.kind] += 1
        if fault.kind == "delay":
            time.sleep(fault.param)
            return self._inner.generate(prompts, scfg)
        if fault.kind == "hang":
            inj._release.wait(timeout=inj.hang_timeout)
            raise WorkerLost(self._name, self._idx, "injected hang released")
        if fault.kind == "kill":
            proc = getattr(self._inner, "proc", None)
            if proc is not None:
                proc.kill()
                # the forwarded call now reads a dead pipe: the transport's
                # own EOF/WorkerLost path is what gets exercised
                return self._inner.generate(prompts, scfg)
            raise WorkerLost(self._name, self._idx, "injected kill")
        if fault.kind == "drop":
            try:
                self._inner.generate(prompts, scfg)
            except Exception:
                pass
            raise WorkerLost(self._name, self._idx, "injected reply drop")
        if fault.kind == "corrupt":
            proc = getattr(self._inner, "proc", None)
            if proc is not None and proc.stdin is not None:
                try:
                    # garbage into the live protocol stream: the child's
                    # framing cap rejects the bogus length header and exits,
                    # and the forwarded call surfaces the desync as
                    # WorkerLost through the REAL framing layer
                    proc.stdin.write(b"\xde\xad\xbe\xef" * 4)
                    proc.stdin.flush()
                except Exception:
                    pass
                return self._inner.generate(prompts, scfg)
            raise WorkerLost(self._name, self._idx, "injected corrupt frame")
        if fault.kind == "dup":
            # duplicate-reply: do the work, hold the reply past any sane
            # deadline budget, then return it LATE -- by then a hedge has
            # won the race and this completion must be dropped as stale
            out = self._inner.generate(prompts, scfg)
            time.sleep(fault.param)
            return out
        raise AssertionError(f"unhandled fault kind {fault.kind!r}")

    def probe(self, payload):
        return self._inner.probe(payload)

    def ping(self):
        return self._inner.ping()

    def close(self):
        return self._inner.close()


def install_chaos(pool: EnginePool, seed: int, *, calls: int = 10,
                  rate: float = 0.25, hold: float = 0.5) -> FaultInjector:
    """The launcher's one-call chaos entry point: seed -> plan -> injector."""
    plan = FaultPlan.seeded(seed, pool.size, calls=calls, rate=rate, hold=hold)
    return FaultInjector(plan).install(pool)
