from .engine import Engine, ServeConfig
from .queue import AdmissionQueue, Request, workload_class
from .router import Dispatch, EngineSlot, Router, router_machine
__all__ = ["AdmissionQueue", "Dispatch", "Engine", "EngineSlot", "Request",
           "Router", "ServeConfig", "router_machine", "workload_class"]
