from .engine import Engine, ServeConfig
from .queue import AdmissionQueue, Request, class_mix, workload_class
from .router import Dispatch, EngineSlot, Router, router_machine
__all__ = ["AdmissionQueue", "Dispatch", "Engine", "EngineSlot", "Request",
           "Router", "ServeConfig", "class_mix", "router_machine",
           "workload_class"]
