from .engine import Engine, ServeConfig
from .pool import (
    EnginePool,
    EngineSlot,
    WorkerLost,
    WorkerSpec,
    null_engine_factory,
    smoke_engine_factory,
)
from .queue import AdmissionQueue, Request, TenantTier, class_mix, workload_class
from .router import Dispatch, Router, router_machine
from .watchdog import DeadlineWatchdog
__all__ = ["AdmissionQueue", "DeadlineWatchdog", "Dispatch", "Engine",
           "EnginePool", "EngineSlot", "Request", "Router", "ServeConfig",
           "TenantTier", "WorkerLost", "WorkerSpec", "class_mix",
           "null_engine_factory", "router_machine", "smoke_engine_factory",
           "workload_class"]
