"""Admission queue for the multi-tenant serving front-end.

Requests carry a tenant and a *workload class* — the (prompt-len, max-new)
pow2 bucket pair.  The class is the unit the router plans over: CEFT treats
each pending class as a task chain, so bucketing is what keeps the per-tick
DAG small (a handful of classes) no matter how many raw requests are queued.

Admission control is per-tenant and global: a tenant that floods the queue
is rejected at submit() without touching other tenants' backlog, and drain()
interleaves tenants so one deep backlog cannot starve the rest.  Tenants may
carry a :class:`TenantTier` (ISSUE 9): the tier's *weight* drives a smooth
weighted-round-robin drain with a hard starvation bound (a non-empty tenant
of weight w is popped at least once per ``ceil(2 x total_weight / w)``
drains — see :meth:`AdmissionQueue.starvation_bound` for the credit-range
argument), and the tier's *SLO* is stamped onto every admitted request so the router
can propagate deadlines backward through its plan.  Uniform weights reduce
the drain exactly to the historical insertion-order round-robin.
Thread-safe: tenants submit from their own threads, the router drains from
its tick loop.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import threading
import time
from collections import OrderedDict, deque

import numpy as np

_IDS = itertools.count()

# Dispatch-attempt sequence ids, monotonic process-wide.  Every attempt to
# serve a request — the original dispatch, a hedged re-dispatch, a requeue's
# re-dispatch — draws a fresh seq here, and completion is first-attempt-wins:
# a later reply for an already-completed request is dropped as stale (counted
# in stats["stale_replies"]) instead of double-completing it.  Single owner so
# router- and pool-level attempt ids can never collide.
_ATTEMPTS = itertools.count(1)


def next_seq() -> int:
    """A fresh dispatch-attempt sequence id (monotonic, never reused)."""
    return next(_ATTEMPTS)


def _pow2ceil(x: int) -> int:
    """Smallest power of two >= x (>= 1)."""
    return 1 << max(0, int(x) - 1).bit_length() if x > 1 else 1


def workload_class(prompt_len: int, max_new: int) -> tuple[int, int]:
    """The (prompt-len, max-new) pow2 bucket pair — the router's task class."""
    return (_pow2ceil(prompt_len), _pow2ceil(max_new))


def moldable_class(wclass: tuple[int, int], split: int) -> tuple[int, int, int]:
    """A workload-class bucket extended with a moldable split degree: the
    (prompt-len, max-new, split) triple a fork-join plan registers under in
    the plan cache's reverse index, *alongside* the base pair (cost deltas
    arrive keyed by the base class and must still dirty every split's plan).
    ``split=1`` is the unsplit prefill->decode chain."""
    return (int(wclass[0]), int(wclass[1]), int(split))


def class_mix(resident: dict) -> tuple:
    """Deterministic (wclass, count) signature of a pending mix.

    The router's steady-state short-circuit key: two ticks with equal mixes
    build byte-identical request DAGs and cost planes, so a clean cached plan
    can be served without touching the planner at all.  Counts are exact, not
    bucketed — serving a plan priced for a different request count would
    break the plan-cache invariant (cached == from-scratch)."""
    return tuple(sorted((wc, len(q)) for wc, q in resident.items()))


@dataclasses.dataclass(frozen=True)
class TenantTier:
    """Admission policy for one tenant: drain weight and optional latency SLO.

    ``weight`` is the tenant's share of drain slots (smooth weighted round-
    robin; 1.0 is the untiered default).  Zero or negative weights are
    rejected at construction — a zero-weight tenant would never win a drain
    slot, i.e. starve forever, which is a config error, not a policy.
    ``slo`` (seconds, end-to-end from submit) is stamped onto every admitted
    request; the router propagates it backward through the planned DAG.
    """
    name: str
    weight: float = 1.0
    slo: float | None = None

    def __post_init__(self):
        w = float(self.weight)
        if not math.isfinite(w) or w <= 0.0:
            raise ValueError(
                f"tier {self.name!r}: weight must be finite and > 0 "
                f"(got {self.weight!r}); a zero-weight tenant would starve")
        if self.slo is not None and not float(self.slo) > 0.0:
            raise ValueError(f"tier {self.name!r}: slo must be > 0 seconds")


@dataclasses.dataclass
class Request:
    tenant: str
    prompt: np.ndarray          # (plen,) int32 token ids
    max_new: int
    rid: int = dataclasses.field(default_factory=lambda: next(_IDS))
    slo: float | None = None    # end-to-end budget (stamped at admission)
    t_submit: float = 0.0       # monotonic admission time (stamped at submit)

    @property
    def wclass(self) -> tuple[int, int]:
        return workload_class(int(self.prompt.shape[0]), int(self.max_new))

    @property
    def deadline(self) -> float | None:
        """Absolute monotonic deadline, or None for best-effort requests."""
        return None if self.slo is None else self.t_submit + self.slo


class AdmissionQueue:
    """Bounded per-tenant FIFOs with (weighted) round-robin drain."""

    def __init__(self, max_pending: int = 256, per_tenant: int = 64,
                 tiers: "dict[str, TenantTier] | None" = None):
        self.max_pending = int(max_pending)
        self.per_tenant = int(per_tenant)
        self.tiers: dict[str, TenantTier] = dict(tiers) if tiers else {}
        for t, tier in self.tiers.items():
            if not isinstance(tier, TenantTier):
                raise TypeError(f"tiers[{t!r}] must be a TenantTier")
        self.rejected = 0
        self._lock = threading.Lock()
        self._pending: OrderedDict[str, deque[Request]] = OrderedDict()
        # smooth-WRR state: per-tenant current credit (nginx-style)
        self._credit: dict[str, float] = {}
        self._n = 0

    def _weight(self, tenant: str) -> float:
        tier = self.tiers.get(tenant)
        return 1.0 if tier is None else float(tier.weight)

    def __len__(self) -> int:
        with self._lock:
            return self._n

    def submit(self, req: Request) -> bool:
        """Admit ``req``; False when the tenant or global bound is hit.

        Admission stamps the request's SLO clock: ``t_submit`` is set (once)
        to the monotonic admission time and a tenant with a tier SLO has it
        copied onto the request unless the request already carries its own —
        the deadline the router propagates is *end-to-end from admission*,
        queueing delay included."""
        with self._lock:
            q = self._pending.get(req.tenant)
            if self._n >= self.max_pending or (q is not None
                                               and len(q) >= self.per_tenant):
                # bounds checked before any insertion: a rejected submit from
                # a never-admitted tenant must not leak a dict entry
                self.rejected += 1
                return False
            if req.t_submit == 0.0:
                req.t_submit = time.monotonic()
            if req.slo is None:
                tier = self.tiers.get(req.tenant)
                if tier is not None:
                    req.slo = tier.slo
            if q is None:
                q = self._pending[req.tenant] = deque()
            q.append(req)
            self._n += 1
            return True

    def starvation_bound(self, tenant: str) -> int:
        """Upper bound on drain slots that can pass over a non-empty tenant:
        ``ceil(2 x total active weight / weight(tenant))``.  Smooth WRR keeps
        every tenant's credit strictly inside (-W, W) for W the total active
        weight; a tenant passed over k times gains k x w credit, so
        k x w < 2W before it must hold the maximum and win a slot.  The
        factor 2 is tight: a tenant with w ~ W still waits up to 2 slots."""
        with self._lock:
            total = sum(self._weight(t) for t, q in self._pending.items() if q)
        total = max(total, self._weight(tenant))
        return int(math.ceil(2.0 * total / self._weight(tenant)))

    def drain(self, limit: int | None = None) -> list[Request]:
        """Pop up to ``limit`` requests, interleaving tenants by tier weight.

        Smooth weighted round-robin (the nginx algorithm): each selection
        adds every non-empty tenant's weight to its credit, the highest
        credit wins (insertion order of first submit breaks ties) and pays
        the total active weight back.  With uniform weights this IS the
        historical insertion-order round-robin, pop for pop; with tiers it
        interleaves proportionally while keeping the starvation bound above.
        Credit persists across drains (so fairness holds across ticks, not
        just within one) and is dropped when a tenant's backlog empties."""
        out: list[Request] = []
        with self._lock:
            budget = self._n if limit is None else min(limit, self._n)
            while budget > 0:
                active = [(t, q) for t, q in self._pending.items() if q]
                if not active:
                    break
                total = 0.0
                best, best_credit = None, -np.inf
                for t, q in active:
                    w = self._weight(t)
                    total += w
                    c = self._credit.get(t, 0.0) + w
                    self._credit[t] = c
                    if c > best_credit:
                        best, best_credit = t, c
                self._credit[best] -= total
                out.append(self._pending[best].popleft())
                self._n -= 1
                budget -= 1
            # drop emptied tenants: a long-lived router with ephemeral tenant
            # ids must not accumulate one permanent dict entry (and one
            # round-robin scan slot) per tenant ever admitted.  Their WRR
            # credit goes with them: a returning tenant starts even, it does
            # not cash in credit banked while it had nothing to serve.
            for t in [t for t, q in self._pending.items() if not q]:
                del self._pending[t]
                self._credit.pop(t, None)
        return out
