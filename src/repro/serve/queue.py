"""Admission queue for the multi-tenant serving front-end.

Requests carry a tenant and a *workload class* — the (prompt-len, max-new)
pow2 bucket pair.  The class is the unit the router plans over: CEFT treats
each pending class as a task chain, so bucketing is what keeps the per-tick
DAG small (a handful of classes) no matter how many raw requests are queued.

Admission control is per-tenant and global: a tenant that floods the queue
is rejected at submit() without touching other tenants' backlog, and drain()
interleaves tenants round-robin so one deep backlog cannot starve the rest.
Thread-safe: tenants submit from their own threads, the router drains from
its tick loop.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import OrderedDict, deque

import numpy as np

_IDS = itertools.count()

# Dispatch-attempt sequence ids, monotonic process-wide.  Every attempt to
# serve a request — the original dispatch, a hedged re-dispatch, a requeue's
# re-dispatch — draws a fresh seq here, and completion is first-attempt-wins:
# a later reply for an already-completed request is dropped as stale (counted
# in stats["stale_replies"]) instead of double-completing it.  Single owner so
# router- and pool-level attempt ids can never collide.
_ATTEMPTS = itertools.count(1)


def next_seq() -> int:
    """A fresh dispatch-attempt sequence id (monotonic, never reused)."""
    return next(_ATTEMPTS)


def _pow2ceil(x: int) -> int:
    """Smallest power of two >= x (>= 1)."""
    return 1 << max(0, int(x) - 1).bit_length() if x > 1 else 1


def workload_class(prompt_len: int, max_new: int) -> tuple[int, int]:
    """The (prompt-len, max-new) pow2 bucket pair — the router's task class."""
    return (_pow2ceil(prompt_len), _pow2ceil(max_new))


def class_mix(resident: dict) -> tuple:
    """Deterministic (wclass, count) signature of a pending mix.

    The router's steady-state short-circuit key: two ticks with equal mixes
    build byte-identical request DAGs and cost planes, so a clean cached plan
    can be served without touching the planner at all.  Counts are exact, not
    bucketed — serving a plan priced for a different request count would
    break the plan-cache invariant (cached == from-scratch)."""
    return tuple(sorted((wc, len(q)) for wc, q in resident.items()))


@dataclasses.dataclass
class Request:
    tenant: str
    prompt: np.ndarray          # (plen,) int32 token ids
    max_new: int
    rid: int = dataclasses.field(default_factory=lambda: next(_IDS))

    @property
    def wclass(self) -> tuple[int, int]:
        return workload_class(int(self.prompt.shape[0]), int(self.max_new))


class AdmissionQueue:
    """Bounded per-tenant FIFOs with round-robin drain."""

    def __init__(self, max_pending: int = 256, per_tenant: int = 64):
        self.max_pending = int(max_pending)
        self.per_tenant = int(per_tenant)
        self.rejected = 0
        self._lock = threading.Lock()
        self._pending: OrderedDict[str, deque[Request]] = OrderedDict()
        self._n = 0

    def __len__(self) -> int:
        with self._lock:
            return self._n

    def submit(self, req: Request) -> bool:
        """Admit ``req``; False when the tenant or global bound is hit."""
        with self._lock:
            q = self._pending.get(req.tenant)
            if self._n >= self.max_pending or (q is not None
                                               and len(q) >= self.per_tenant):
                # bounds checked before any insertion: a rejected submit from
                # a never-admitted tenant must not leak a dict entry
                self.rejected += 1
                return False
            if q is None:
                q = self._pending[req.tenant] = deque()
            q.append(req)
            self._n += 1
            return True

    def drain(self, limit: int | None = None) -> list[Request]:
        """Pop up to ``limit`` requests, interleaving tenants round-robin
        (insertion order of first submit) for cross-tenant fairness."""
        out: list[Request] = []
        with self._lock:
            budget = self._n if limit is None else min(limit, self._n)
            while budget > 0:
                progressed = False
                for q in self._pending.values():
                    if q and budget > 0:
                        out.append(q.popleft())
                        self._n -= 1
                        budget -= 1
                        progressed = True
                if not progressed:
                    break
            # drop emptied tenants: a long-lived router with ephemeral tenant
            # ids must not accumulate one permanent dict entry (and one
            # round-robin scan slot) per tenant ever admitted
            for t in [t for t, q in self._pending.items() if not q]:
                del self._pending[t]
        return out
