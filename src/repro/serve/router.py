"""CEFT-routed multi-tenant serving front-end (the paper's planner run
*online* as a dispatch policy).

The mutual-inclusivity claim, applied to serving: a useful critical path of
the pending work must carry its own mapping of tasks to processor classes.
Here the tasks are request *workload classes* (prompt-len/max-new buckets,
see repro.serve.queue) and the processor classes are the pool's engines —
each pinned to its own sharding profile and/or architecture, made safe to
run concurrently by the scoped-profile substrate.  Every tick the router:

  1. admits the queue's arrivals into per-class *resident* FIFOs (incremental
     admission: residents persist across ticks; ``tick_budget`` bounds how
     many leave per tick),
  2. models the resident mix as a small task DAG (one prefill -> decode
     chain per class; edge data = the KV handoff volume),
  3. prices the DAG with an online EWMA cost table (per-token rates measured
     from real dispatches, shared machinery with repro.sched.straggler) and
     the StragglerMonitor's per-engine slowdown factors,
  4. plans through the unified plan cache (repro.sched.plancache): an
     unchanged mix with no cost/slowdown delta since the cached sweep is
     served straight from cache (a steady-state tick runs ZERO sweeps and
     costs O(classes + budget), independent of how many requests are
     resident); deltas invalidate only the affected plans through the
     cache's reverse index, and a changed plane re-sweeps from its dirty
     frontier, and
  5. dispatches: critical-path classes go to the path's own engine class,
     off-path classes to their earliest-finish class, and same-class
     requests coalesce into micro-batches whose added latency stays bounded
     by the CEFT path length (a micro-batch never grows past the point where
     it would itself become the critical path).

A degraded engine (StragglerMonitor threshold trip) therefore sheds
critical-path work automatically: its comp column inflates, CEFT maps the
path elsewhere, and the dispatch follows the path.

The SLO plane (ISSUE 9) rides on the same plan: tenants may carry
:class:`~repro.serve.queue.TenantTier`\\ s (weighted drain + latency SLOs
stamped at admission), each cached plan's backward deadline propagation
(repro.sched.deadlines, memoized on the plan-cache entry) assigns every
class a latest start/finish and slack, watchdog budgets are armed from the
propagated latest-finish instead of the flat ``deadline_factor x span``,
and degraded engines shed their most-slack dispatches first — both at tick
time (``_slo_shed``) and on the overdue ladder (slack-rich work requeues at
strike 1, SLO-critical work hedges like critical-path work).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Sequence

import numpy as np

from ..core import planners
from ..core.ceft import CeftResult
from ..core.ceft_jax import request_graph
from ..core.machine import Machine
from ..core.taskgraph import moldable_fork_join_arrays
from ..sched.deadlines import DeadlineSchedule, propagate_deadlines
from ..sched.plancache import PlanCache, machine_fingerprint
from ..sched.straggler import EwmaCostTable, StragglerMonitor
from .engine import ServeConfig
from .pool import EnginePool, EngineSlot, WorkerLost
from .queue import AdmissionQueue, Request, class_mix, moldable_class, next_seq
from .watchdog import DeadlineWatchdog, InflightEntry


@dataclasses.dataclass
class Dispatch:
    engine: int                  # slot index == CEFT processor class
    requests: list[Request]
    wclass: tuple[int, int]
    on_critical_path: bool
    node_prefill: int            # this class's vertex ids in the planned DAG
    node_decode: int             # (node_prefill = first chunk when split > 1)
    split: int = 1               # planner-chosen moldable prefill split degree
    # SLO plane (ISSUE 9): the tightest absolute deadline among the batch's
    # requests (None = best-effort) and the class's structural slack from the
    # backward deadline propagation (inf when no propagation is available)
    deadline: float | None = None
    slack: float = float("inf")


def router_machine(P: int, *, kv_bw: float = 1e4, latency: float = 1e-3) -> Machine:
    """The pool as a CEFT machine: one class per engine (count 1), uniform
    KV-handoff bandwidth (tokens/s) and dispatch latency between engines."""
    return Machine(
        L=np.full(P, latency, np.float64),
        bw=np.full((P, P), kv_bw, np.float64),
        counts=np.ones(P, np.int64),
    )


class Router:
    """Plans over the placement plane and owns the admission queue and cost
    model; turns each tick's pending requests into CEFT-planned dispatches.

    The router no longer constructs or holds engines: ``pool`` (an
    :class:`~repro.serve.pool.EnginePool`, or a plain ``EngineSlot`` list
    wrapped into one) owns worker lifecycle and the measured comm plane, and
    every plan prices against ``pool.machine()`` — a snapshot that only
    changes when the pool's shape or a quantized measurement does, so the
    plan cache's machine fingerprints stay meaningful."""

    def __init__(self, pool: EnginePool | Sequence[EngineSlot], *,
                 machine: Machine | None = None,
                 queue: AdmissionQueue | None = None, alpha: float = 0.3,
                 default_rate: float = 1e-3, max_batch: int = 8,
                 latency_slack: float = 1.0, straggler_threshold: float = 1.3,
                 plancache: PlanCache | None = None,
                 tick_budget: int | None = None,
                 deadline_factor: float | None = None, hedge: bool = True,
                 min_deadline: float = 0.05, wd_poll: float = 0.01,
                 watchdog: DeadlineWatchdog | None = None,
                 planner: str = "ceft_cpop", max_split: int = 1):
        if not isinstance(pool, EnginePool):
            if not pool:
                raise ValueError("router needs at least one engine slot")
            pool = EnginePool.from_slots(pool, machine=machine)
        elif machine is not None:
            raise ValueError("pass machine= to the pool, not past it")
        self.pool = pool
        if not self.pool.size:
            raise ValueError("router needs at least one pool worker")
        P = self.pool.size
        if self.machine.P != P:
            raise ValueError(f"machine has {self.machine.P} classes for {P} workers")
        self.queue = queue if queue is not None else AdmissionQueue()
        self.costs = EwmaCostTable(P, alpha=alpha, default=default_rate)
        self.monitor = StragglerMonitor(P, threshold=straggler_threshold)
        self.plancache = plancache if plancache is not None else PlanCache()
        # a measured rate delta dirties exactly the cached plans whose DAG
        # contains that workload class (the cache's reverse index)
        self.costs.add_listener(self._on_cost_delta)
        # pool lifecycle deltas (loss, launch, drain) degrade/revive the
        # matching straggler column and dirty the cached plans
        self.pool.add_listener(self._on_pool_event)
        # tick_budget=None keeps the historical dispatch-everything tick;
        # an integer bounds dispatches per tick, split round-robin across
        # classes, with the remainder staying resident for later ticks
        self.tick_budget = None if tick_budget is None else max(1, int(tick_budget))
        self.resident: dict[tuple[int, int], deque[Request]] = {}
        self.max_batch = int(max_batch)
        self.latency_slack = float(latency_slack)
        # planner by registry name (fail fast on typos) + moldable split axis:
        # candidate degrees are the powers of two up to max_split, each priced
        # as its own fork-join plan; the tick keeps the degree whose realized
        # plan finishes first (ties -> smallest degree, so max_split=1 is
        # byte-identical to the historical unsplit router)
        self.planner = planners.get_planner(planner).name
        self.max_split = max(1, int(max_split))
        self._degrees = [d for d in (1, 2, 4, 8, 16, 32)
                         if d <= self.max_split]
        self._slow = np.ones(P)
        self._P = P
        self._m_snapshot = self.machine
        self.stats = {"plans": 0, "degraded_plans": 0, "dispatches": 0,
                      "coalesced": 0, "split": 0, "shed": 0, "ticks": 0,
                      "cache_hits": 0, "invalidations": 0,
                      "partial_sweeps": 0, "resident": 0, "requeued": 0,
                      "overdue": 0, "overdue_cp": 0, "hedges": 0,
                      "stale_replies": 0, "completions": 0,
                      "watchdog_lost": 0, "clamped_budgets": 0,
                      "slo_shed": 0, "slo_hedges": 0, "split_degree": 1,
                      "moldable_plans": 0}
        self.failures: list[tuple[str, BaseException]] = []
        # deadline watchdog (None = disarmed: serve() is the plain PR 7 loop).
        # deadline_factor arms it: every dispatch carries a deadline derived
        # from its planned span under the current cost table x slowdowns, and
        # the monitor thread escalates overdue attempts (hedge / report /
        # requeue / mark_lost -- see _on_overdue).
        self.hedge = bool(hedge)
        self.watchdog = watchdog
        if self.watchdog is None and deadline_factor is not None:
            self.watchdog = DeadlineWatchdog(
                deadline_factor=float(deadline_factor),
                min_deadline=float(min_deadline), poll_interval=float(wd_poll))
        if self.watchdog is not None:
            self.watchdog.on_overdue = self._on_overdue
        self._serve_lock = threading.Lock()
        self._serve_done: dict[int, np.ndarray] | None = None
        self._wd_requeue: list[Dispatch] = []
        self._hedge_threads: list[threading.Thread] = []
        self.last_plan: CeftResult | None = None
        self.last_nominal: CeftResult | None = None
        self.last_dag: tuple | None = None
        self.last_groups: list | None = None
        self._plan_sig: tuple | None = None    # mix the cached plan priced
        self._plan_comp: np.ndarray | None = None
        self._chosen: dict | None = None       # class index -> (engine, on_path)
        self._entry = None                     # the cached plan's PlanEntry
        self._plan_split = 1                   # the cached plan's split degree

    @property
    def machine(self) -> Machine:
        """The pool's current Machine snapshot (the placement plane view)."""
        return self.pool.machine()

    @property
    def slots(self) -> list[EngineSlot]:
        """Engine-slot view of the pool (compat: slot index == CEFT class)."""
        return self.pool.slots

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> bool:
        return self.queue.submit(req)

    # ------------------------------------------------------------ cost model
    def observe(self, engine: int, wclass: tuple[int, int], seconds: float,
                tokens: int) -> None:
        """Fold one measured dispatch into the EWMA table as a per-token rate."""
        self.costs.update(wclass, engine, seconds / max(tokens, 1))

    def _on_cost_delta(self, wclass, engine: int) -> None:
        """EwmaCostTable listener: dirty the cached plans whose DAG contains
        the updated class.  Advisory only — the plan cache byte-compares the
        cost plane before serving anything, so over-invalidation costs a
        re-sweep and under-invalidation is impossible."""
        self.stats["invalidations"] += self.plancache.invalidate(wclass=wclass)

    def observe_step(self, engine_times: np.ndarray) -> np.ndarray:
        """Per-engine health signal (e.g. step times) through the straggler
        monitor; the returned slowdown factors (>= 1) scale the cost table's
        engine columns on every subsequent plan, so a degraded engine sheds
        critical-path work."""
        old = self._slow
        self._slow = self.monitor.observe(np.asarray(engine_times, np.float64))
        if not np.array_equal(old, self._slow):
            # a slowdown delta rescales whole comp columns: every cached plan
            # on this machine is affected, not just one workload class
            self.stats["invalidations"] += self.plancache.invalidate(
                engine=int(np.argmax(self._slow)))
        return self._slow

    # ----------------------------------------------------------- pool deltas
    def _on_pool_event(self, event: str, payload) -> None:
        """EnginePool listener.  Loss/drain fully degrade the worker's class
        column (the straggler plane routes the critical path around it — the
        batched nominal+degraded re-plan IS the failover path); launch
        revives the column and forgets the previous occupant's rates.  All
        three dirty the cached plans and drop the steady-state signature."""
        if event == "machine":
            # a measured comm-plane delta crossed a quantization bucket: the
            # superseded snapshot's plans can only be stale short-circuits
            self.stats["invalidations"] += self.plancache.invalidate(
                machine_fp=machine_fingerprint(payload))
        elif event in ("lost", "drain"):
            self._slow = self.monitor.mark_lost(int(payload))
            self.stats["invalidations"] += self.plancache.invalidate(
                engine=int(payload))
        elif event == "launch":
            self.monitor.revive(int(payload))
            self.costs.reset_class(int(payload))
            self._slow = self.monitor.slowdowns()
            self.stats["invalidations"] += self.plancache.invalidate()
        self._plan_sig = None

    def _sync_pool(self) -> None:
        """Re-align the planning state with the pool's current shape and
        Machine snapshot (workers may have launched, drained, or died since
        the last tick; probes may have moved the measured comm plane)."""
        P = self.pool.size
        if P != self._P:
            self._P = P
            self.costs.ensure_classes(P)
            self.monitor.ensure_classes(P)
            self._plan_sig = None
        slow = self.monitor.slowdowns()
        if len(slow) < P:
            self.monitor.ensure_classes(P)
            slow = self.monitor.slowdowns()
        self._slow = slow[:P]
        m = self.pool.machine()
        if m is not self._m_snapshot:
            self.stats["invalidations"] += self.plancache.invalidate(
                machine_fp=machine_fingerprint(self._m_snapshot))
            self._m_snapshot = m
            self._plan_sig = None

    # --------------------------------------------------------------- planning
    def build_dag(self, groups: list[tuple[tuple[int, int], list[Request]]],
                  split: int = 1):
        """The pending batch as a task DAG: per class a moldable fork-join —
        ``split`` parallel prefill chunks (vertices ``i*split ..``) joining
        into one decode (vertex ``G*split + i``), edge data = the chunk's
        prompt-token volume (the KV handoff volume if the decode lands on a
        different engine), comp from the EWMA per-token rates x token
        volumes.  ``split=1`` is the historical prefill (vertex i) -> decode
        (vertex G+i) chain, byte-for-byte.  The returned plane is *nominal*
        (unscaled): ``_plan`` applies the monitor's slowdown factors, so the
        nominal plane stays byte-stable across slowdown changes and the plan
        cache's nominal slot keeps hitting.

        Token volumes are *bucket-sized* (wclass bound x request count), not
        exact sums: the class is the task, and bucketing keeps the DAG
        content identical across ticks with the same class mix + counts, so
        the content-keyed graph store actually hits on real traffic
        (exact per-tick prompt sums would miss it every tick)."""
        G = len(groups)
        d = max(1, int(split))
        rates = self.costs.comp_matrix([wc for wc, _ in groups])
        volumes = np.array([float(wc[0] * len(reqs)) for wc, reqs in groups],
                           np.float64)
        n, src, dst, data = moldable_fork_join_arrays(volumes, d)
        comp = np.zeros((n, self.machine.P), np.float64)
        comp[:G * d] = np.repeat(rates, d, axis=0) * data[:G * d, None]
        for i, (wc, reqs) in enumerate(groups):
            comp[G * d + i] = rates[i] * float(wc[1] * len(reqs))
        return n, src, dst, data, comp

    def _plan(self, classes, n, src, dst, data, comp_nominal, *,
              split: int = 1):
        """One plan-cache pass over the tick's DAG; scenario-split (degraded
        + nominal planes, each through its own cache slot over the same
        graph) while any engine trips the monitor, so the shed critical-path
        work is observable against the nominal plan.  Split-degree plans get
        their own slots and additionally register under their moldable
        classes; the base classes stay on every plan so a cost delta keyed by
        the base class dirties all of a class's split variants.

        Returns ``(res, comp, nom, entry)`` — the caller owns publishing the
        winning candidate to ``last_plan``/``last_nominal``/``_entry``."""
        if split > 1:
            classes = list(classes) + [moldable_class(wc, split)
                                       for wc in classes]
            slot_nom, slot_deg = ("router", split), ("router-degraded", split)
        else:
            slot_nom, slot_deg = "router", "router-degraded"
        g = request_graph(n, src, dst, data)
        comp = comp_nominal * self._slow[None, :]
        degraded_mode = bool((self._slow >= self.monitor.threshold).any())
        if degraded_mode:
            res, status, entry = self.plancache.plan(
                g, comp, self.machine, slot=slot_deg, classes=classes,
                planner=self.planner)
            nom, _, _ = self.plancache.plan(
                g, comp_nominal, self.machine, slot=slot_nom, classes=classes,
                planner=self.planner)
            self.stats["degraded_plans"] += 1
            self.stats["shed"] += sum(
                1 for t, p in res.path if nom.assignment.get(t, p) != p)
        else:
            res, status, entry = self.plancache.plan(
                g, comp, self.machine, slot=slot_nom, classes=classes,
                planner=self.planner)
            nom = None
        self.stats["plans"] += 1
        if status == "hit":
            self.stats["cache_hits"] += 1
        elif status == "partial":
            self.stats["partial_sweeps"] += 1
        return res, comp, nom, entry

    def _realized_makespan(self, res, entry) -> float:
        """The candidate plan's realized finish time — the planner's full
        schedule (instances, contention included) over the entry's own cost
        plane, memoized per plan entry so steady traffic never re-schedules.
        This is the moldable degree-selection metric: the class-view DP alone
        always rewards more splitting (chunks never contend in the class
        view), the realized schedule prices the contention."""
        sched = entry.derived.get("sched")
        if sched is None:
            sched = entry.derived["sched"] = planners.realize(
                self.planner, entry.graph,
                entry.comp32.astype(np.float64), entry.machine, res)
        return float(sched.makespan)

    def _choose(self, G: int, res: CeftResult, comp: np.ndarray,
                split: int = 1) -> dict:
        """The ceft_cpop split, serving-side: critical-path classes are
        pinned to the path's own engine; everything else takes its earliest-
        finish class *given the load already placed this tick* (pure argmin
        over res.ceft would pile every tied class onto engine 0).  With a
        moldable split, a class is on-path when ANY of its chunks (or its
        decode) is, and its placed load sums over all its chunk vertices."""
        d = max(1, int(split))
        assign = res.assignment                    # critical path's own mapping
        load = np.zeros(self.machine.P)
        chosen: dict[int, tuple[int, bool]] = {}
        on_path = [i for i in range(G)
                   if G * d + i in assign
                   or any(i * d + j in assign for j in range(d))]
        for i in on_path + [i for i in range(G) if i not in on_path]:
            pres = range(i * d, i * d + d)
            dec = G * d + i
            if i in on_path:                       # shed to the path's class
                cls = int(assign.get(
                    dec, next((assign[p] for p in pres if p in assign), 0)))
            else:                                  # earliest finish incl. load
                cls = int(np.argmin(res.ceft[dec] + load))
            chosen[i] = (cls, i in on_path)
            load[cls] += comp[list(pres), cls].sum() + comp[dec, cls]
        return chosen

    # --------------------------------------------------------------- the tick
    def tick(self) -> list[Dispatch]:
        """Admit, plan (or serve the cached plan), and form micro-batches up
        to ``tick_budget``; returns the dispatch list (execution is separate
        -- see run_dispatch / serve).

        The steady-state guarantee (README "Incremental planning"): when the
        resident mix matches the cached plan's and no cost/slowdown delta
        has dirtied it, the tick serves the plan straight from cache — zero
        sweeps, no cost-plane build, cost O(classes + budget) independent of
        the resident count (gated by the jax_csr_router_steady bench row)."""
        if self.pool.autoscale:
            backlog = len(self.queue) + sum(len(q) for q in self.resident.values())
            self.pool.maybe_autoscale(backlog)
        self._sync_pool()
        for r in self.queue.drain():
            self.resident.setdefault(r.wclass, deque()).append(r)
        self.stats["ticks"] += 1
        self.stats["resident"] = sum(len(q) for q in self.resident.values())
        if not self.resident:
            return []
        sig = class_mix(self.resident)
        entry = self._entry
        if sig == self._plan_sig and entry is not None and not entry.dirty:
            # steady state: same mix, no relevant delta since the cached
            # sweep (observe()/observe_step() dirty the entry through the
            # cache's reverse index, so staleness cannot be served)
            self.stats["cache_hits"] += 1
            res, comp, chosen = self.last_plan, self._plan_comp, self._chosen
            split = self._plan_split
        else:
            groups = [(wc, list(self.resident[wc]))
                      for wc in sorted(self.resident)]   # deterministic order
            wcs = [wc for wc, _ in groups]
            # moldable split-degree selection: price every candidate degree's
            # fork-join plan (each through its own cache slot) and keep the
            # one whose REALIZED schedule finishes first — strictly first, so
            # ties fall to the smallest degree and max_split=1 reproduces the
            # historical single-candidate tick exactly
            best = None
            for dgr in self._degrees:
                dag = self.build_dag(groups, split=dgr)
                n, src, dst, data, comp_nominal = dag
                cand_res, cand_comp, cand_nom, cand_entry = self._plan(
                    wcs, n, src, dst, data, comp_nominal, split=dgr)
                if dgr > 1:
                    self.stats["moldable_plans"] += 1
                fin = (self._realized_makespan(cand_res, cand_entry)
                       if len(self._degrees) > 1 else 0.0)
                if best is None or fin < best[0] - 1e-12 * max(1.0, best[0]):
                    best = (fin, dgr, dag, cand_res, cand_comp, cand_nom,
                            cand_entry)
            _, split, dag, res, comp, nom, entry = best
            self.last_dag = dag
            self.last_groups = groups
            self.last_plan, self.last_nominal = res, nom
            self._entry = entry
            self.stats["split_degree"] = split
            chosen = self._choose(len(groups), res, comp, split)
            self._plan_sig, self._plan_comp, self._chosen = sig, comp, chosen
            self._plan_split = split
        classes = sorted(self.resident)
        G = len(classes)
        # round-robin budget split across classes (same fairness idiom as
        # AdmissionQueue.drain): a bounded tick must not starve late classes
        takes = dict.fromkeys(classes, 0)
        if self.tick_budget is None:
            for wc in classes:
                takes[wc] = len(self.resident[wc])
        else:
            b = self.tick_budget
            while b > 0:
                progressed = False
                for wc in classes:
                    if b > 0 and takes[wc] < len(self.resident[wc]):
                        takes[wc] += 1
                        b -= 1
                        progressed = True
                if not progressed:
                    break
        degraded_mode = bool((self._slow >= self.monitor.threshold).any())
        out: list[Dispatch] = []
        for i, wc in enumerate(classes):
            if takes[wc] == 0:
                continue
            q = self.resident[wc]
            rs = [q.popleft() for _ in range(takes[wc])]
            pre, dec = i * split, G * split + i
            cls, on_cp = chosen[i]
            # micro-batch formation: coalesce class-mates while the batch's
            # estimated service time stays within latency_slack x the CEFT
            # path length -- growing past that would make the batch itself
            # the critical path, trading throughput for unbounded latency
            rate = float((self.costs.row(wc) * self._slow)[cls])
            per_req = max(rate * (wc[0] + wc[1]), 1e-12)
            bound = max(1, int(self.latency_slack * res.cpl / per_req))
            size = max(1, min(self.max_batch, bound))
            # micro-batches hold one *exact* prompt length each: the engines
            # have no padding mask, so mixing lengths inside one generate()
            # would condition shorter requests on filler tokens
            by_len: dict[int, list[Request]] = {}
            for r in rs:
                by_len.setdefault(int(r.prompt.shape[0]), []).append(r)
            chunks: list[list[Request]] = []
            for _, rl in sorted(by_len.items()):
                if size < len(rl):      # the latency bound itself partitioned
                    self.stats["split"] += 1
                chunks.extend(rl[k:k + size] for k in range(0, len(rl), size))
            for chunk in chunks:
                dl: float | None = None
                for r in chunk:
                    rd = r.deadline
                    if rd is not None:
                        dl = rd if dl is None else min(dl, rd)
                out.append(Dispatch(int(cls), chunk, wc, on_cp, pre, dec,
                                    split=split, deadline=dl))
        # the SLO plane only engages when a dispatch carries a deadline or
        # an engine is degraded: a best-effort steady-state tick must stay
        # O(classes + budget), so the propagation (memoized per plan entry)
        # is not even consulted on that path
        if degraded_mode or any(d.deadline is not None for d in out):
            D = self._deadline_view()
            if D is not None:
                for d in out:
                    d.slack = float(D.slack[d.node_decode])
        if degraded_mode:
            out = self._slo_shed(out)
        for d in out:
            self.stats["dispatches"] += 1
            self.stats["coalesced"] += len(d.requests) - 1
        # emptied classes leave the resident mix (and thus the plan signature)
        for wc in [wc for wc, q in self.resident.items() if not q]:
            del self.resident[wc]
        self.stats["resident"] = sum(len(q) for q in self.resident.values())
        return out

    def _slo_shed(self, out: list[Dispatch]) -> list[Dispatch]:
        """Slack-keyed shedding off degraded engines (ISSUE 9): of the
        dispatches the plan still placed on a monitor-degraded engine, the
        MOST-slack ones are held back (requeued for the next tick's re-plan)
        first — they can absorb the extra tick without missing their
        deadline, while the least-slack work keeps its slot rather than
        gambling its remaining budget on a requeue.  Bounded: a healthy
        engine must exist (else deferring is pure livelock) and at least one
        dispatch always goes out, so every tick makes progress."""
        slow_eng = {i for i in range(len(self._slow))
                    if self._slow[i] >= self.monitor.threshold}
        healthy = [i for i in self.pool.live_indices() if i not in slow_eng]
        if not healthy or len(out) <= 1:
            return out
        candidates = sorted(
            (d for d in out
             if d.engine in slow_eng and d.slack > self.planned_span(d)),
            key=lambda d: -d.slack)
        shed: list[Dispatch] = []
        for d in candidates:
            if len(out) - len(shed) <= 1:
                break
            shed.append(d)
        if shed:
            ids = {id(d) for d in shed}
            out = [d for d in out if id(d) not in ids]
            self._requeue(shed)
            self.stats["slo_shed"] += sum(len(d.requests) for d in shed)
        return out

    # -------------------------------------------------------------- execution
    def run_dispatch(self, d: Dispatch) -> dict[int, np.ndarray]:
        """Execute one micro-batch on its planned engine, feed the measured
        per-token rate back into the cost table, return {rid: tokens}."""
        lens = {int(r.prompt.shape[0]) for r in d.requests}
        if len(lens) != 1:
            # no padding mask in the engines: filler tokens would corrupt the
            # shorter requests' generations (tick() never mixes lengths)
            raise ValueError(f"micro-batch mixes prompt lengths {sorted(lens)}")
        prompts = np.stack([r.prompt for r in d.requests]).astype(np.int32)
        plen = prompts.shape[1]
        max_new = max(int(r.max_new) for r in d.requests)
        t0 = time.perf_counter()
        toks = self.pool.generate(d.engine, prompts,
                                  ServeConfig(max_new_tokens=max_new))
        dt = time.perf_counter() - t0
        # the engine generates the batch max_new for every row; charge the
        # rate for the work actually done and trim each row to its own budget
        self.observe(d.engine, d.wclass, dt, len(d.requests) * (plen + max_new))
        toks = np.asarray(toks)
        return {r.rid: toks[b, : plen + int(r.max_new)]
                for b, r in enumerate(d.requests)}

    def _requeue(self, ds: list[Dispatch],
                 done: dict[int, np.ndarray] | None = None) -> None:
        """Put un-served dispatches back at the FRONT of their resident
        queues (FIFO order preserved) so the next tick re-plans them.
        ``done`` filters out requests another attempt (a hedge, a recovered
        original) already completed — re-serving those would waste work and
        break the exactly-once accounting."""
        for d in ds:
            reqs = (d.requests if done is None
                    else [r for r in d.requests if r.rid not in done])
            if not reqs:
                continue
            q = self.resident.setdefault(d.wclass, deque())
            for r in reversed(reqs):
                q.appendleft(r)
            self.stats["requeued"] += len(reqs)
        self.stats["resident"] = sum(len(q) for q in self.resident.values())

    # ------------------------------------------------------- deadline watchdog
    def planned_span(self, d: Dispatch) -> float:
        """Expected service seconds for one micro-batch under the current
        cost table x straggler slowdowns — the same numbers its plan was
        priced with, so the watchdog enforces exactly what the plan
        promised.  The slowdown factor is capped: a monitor-degraded (or
        LOST-column) engine would otherwise inflate the budget toward
        infinity and disarm the watchdog exactly when it matters most.
        Hitting the cap is counted (``stats["clamped_budgets"]``): a clamped
        budget under-states a genuinely slower engine's span, so SLO misses
        caused by the cap must be observable, not silent."""
        rate = float(self.costs.row(d.wclass)[d.engine])
        slow = float(self._slow[d.engine]) if d.engine < len(self._slow) else 1.0
        if slow > 10.0:
            self.stats["clamped_budgets"] += 1
        return (rate * min(slow, 10.0)
                * len(d.requests) * (d.wclass[0] + d.wclass[1]))

    def _deadline_view(self) -> DeadlineSchedule | None:
        """The cached plan's backward deadline propagation, memoized on the
        plan-cache entry (``PlanEntry.derived``) so a steady-state tick never
        re-propagates: re-sweeps build a fresh entry (fresh memo slot) and
        byte-equal hits return the same entry, so the memo can never serve a
        schedule inconsistent with the plan it annotates."""
        entry = self._entry
        if entry is None:
            return None
        D = entry.derived.get("deadlines")
        if D is None:
            D = propagate_deadlines(entry.graph, entry.comp32, entry.machine,
                                    entry.result)
            entry.derived["deadlines"] = D
        return D

    def dispatch_budget(self, d: Dispatch) -> float:
        """The watchdog budget for one dispatch: the flat
        ``deadline_factor x planned_span`` when the batch is best-effort,
        else the tighter of that and the SLO's propagated latest-finish —
        ``latest_finish(decode) + remaining - makespan`` shifts the plan-
        relative latest finish onto the request's remaining budget (latest
        times are affine in the horizon, see repro.sched.deadlines).  Floor-
        clamped by ``min_deadline`` so an already-blown SLO degrades to the
        fastest ladder, not a zero budget."""
        wd = self.watchdog
        flat = wd.budget(self.planned_span(d))
        if d.deadline is None:
            return flat
        rem = d.deadline - time.monotonic()
        D = self._deadline_view()
        if D is not None:
            rem = D.latest_finish_for(d.node_decode, rem)
        return max(wd.min_deadline, min(flat, rem))

    def _complete(self, d: Dispatch, out: dict[int, np.ndarray]) -> None:
        """First-attempt-wins completion: a rid already completed (by the
        hedge or the original, whichever returned first) has its late
        duplicate dropped and counted, never overwritten."""
        with self._serve_lock:
            if self._serve_done is None:
                return
            for rid, toks in out.items():
                if rid in self._serve_done:
                    self.stats["stale_replies"] += 1
                else:
                    self._serve_done[rid] = toks
                    self.stats["completions"] += 1

    def _on_overdue(self, entry: InflightEntry, now: float) -> None:
        """Watchdog callback — the escalation ladder, one rung per strike,
        keyed on the dispatch's remaining SLO budget where it has one:

        1. report the offender to the straggler monitor (its column trips
           the threshold, so the next plan sheds work off it); then either
           HEDGE — critical-path work, or SLO-critical work whose remaining
           budget cannot survive another strike (rem < budget): duplicate to
           the degraded plane's best alternate now, first result wins — or
           SHED — slack-rich work (rem >= 2 budgets): requeue immediately,
           it can absorb a re-plan round-trip, so it leaves the degraded
           engine first.  Best-effort / middling-slack work just waits for
           rung 2 (the historical ladder);
        2. requeue the dispatch — the next tick re-plans it elsewhere
           (first result wins; the stuck original is dropped as stale);
        3. the worker is treated as hung for good: mark_lost degrades its
           column and the entry leaves the watchdog.

        Runs on the monitor thread: it only touches the serve lock and the
        pool/monitor's own synchronized entry points; tick-side state (the
        resident queues) is reached via the ``_wd_requeue`` hand-off list
        drained on the serve thread."""
        d: Dispatch = entry.payload
        self.stats["overdue"] += 1
        if entry.on_critical_path:
            self.stats["overdue_cp"] += 1
        if entry.strikes == 1:
            self.monitor.report_overdue(entry.engine)
            self.stats["invalidations"] += self.plancache.invalidate(
                engine=entry.engine)
            self._plan_sig = None
            rem = None if d.deadline is None else d.deadline - now
            slo_critical = rem is not None and rem < entry.budget
            if ((entry.on_critical_path or slo_critical)
                    and self.hedge and not entry.hedged):
                entry.hedged = True
                if slo_critical and not entry.on_critical_path:
                    self.stats["slo_hedges"] += 1
                self._launch_hedge(entry)
            elif rem is not None and rem >= 2.0 * entry.budget:
                entry.shed = True
                self.stats["slo_shed"] += len(d.requests)
                with self._serve_lock:
                    self._wd_requeue.append(d)
        elif entry.strikes == 2:
            if not entry.shed:      # a strike-1 shed already requeued it
                with self._serve_lock:
                    self._wd_requeue.append(d)
        else:
            self.stats["watchdog_lost"] += 1
            self.watchdog.disarm(entry.seq)
            try:
                self.pool.mark_lost(
                    entry.engine,
                    f"watchdog: overdue past {entry.strikes} deadline budgets")
            except Exception:
                pass

    def _hedge_target(self, d: Dispatch) -> int | None:
        """The engine the batched degraded plane names as the best alternate
        for this dispatch's class — the same nominal+degraded re-plan the
        pool-loss path uses, re-priced with the offender's column degraded
        to LOST, run through a TRANSIENT (store=False) cache pass so hedge
        pricing can never poison the cached tick plans."""
        live = set(self.pool.live_indices())
        live.discard(d.engine)
        if not live:
            return None
        if self.last_dag is not None and self.last_groups is not None:
            try:
                n, src, dst, data, comp_nominal = self.last_dag
                slow = np.array(self._slow, np.float64, copy=True)
                if d.engine < len(slow):
                    slow[d.engine] = max(slow[d.engine], 1e6)
                comp = comp_nominal * slow[None, :]
                g = request_graph(n, src, dst, data)
                res, _, _ = self.plancache.plan(
                    g, comp, self._m_snapshot, slot="router-hedge",
                    classes=[wc for wc, _ in self.last_groups], store=False,
                    planner=self.planner)
                alt = res.assignment.get(d.node_decode,
                                         res.assignment.get(d.node_prefill))
                if alt is not None and int(alt) in live:
                    return int(alt)
                # the degraded path moved off this class entirely: take the
                # earliest-finish live engine for the decode vertex instead
                for c in np.argsort(res.ceft[d.node_decode]):
                    if int(c) in live:
                        return int(c)
            except Exception:
                pass
        return self._fallback_target(d, live)

    def _fallback_target(self, d: Dispatch, live: set[int]) -> int | None:
        """Rate-based alternate when no planned DAG is available (first-tick
        races): cheapest live engine for the class under current slowdowns."""
        if not live:
            return None
        row = self.costs.row(d.wclass)
        row = row * self._slow[: len(row)]
        for c in np.argsort(row):
            if int(c) in live:
                return int(c)
        return next(iter(live))

    def _launch_hedge(self, entry: InflightEntry) -> None:
        """Speculatively re-send an overdue critical-path dispatch to the
        degraded plane's best alternate.  First result wins via _complete's
        rid dedup; the hedge itself is armed on the watchdog (off-path, so
        it can never hedge recursively) and its failure requeues instead of
        raising — the original attempt (or a later requeue) still owns the
        requests."""
        d: Dispatch = entry.payload
        alt = self._hedge_target(d)
        if alt is None:
            return
        clone = dataclasses.replace(d, engine=int(alt))
        self.stats["hedges"] += 1

        def run():
            seq = next_seq()
            self.watchdog.arm(seq, clone, planned_span=self.planned_span(clone),
                              engine=clone.engine, on_critical_path=False,
                              budget=self.dispatch_budget(clone))
            try:
                out = self.run_dispatch(clone)
            except BaseException:
                with self._serve_lock:
                    self._wd_requeue.append(clone)
                return
            finally:
                self.watchdog.disarm(seq)
            self._complete(clone, out)

        t = threading.Thread(target=run, name=f"hedge-{alt}", daemon=True)
        self._hedge_threads.append(t)
        t.start()

    def serve(self, max_ticks: int = 64) -> dict[int, np.ndarray]:
        """Tick until the queue AND residents are empty (or max_ticks): the
        launcher's loop.  Disarmed (no watchdog) this IS the historical loop
        — byte-for-byte the PR 7 behaviour; armed it adds deadline
        enforcement around the identical planning pipeline (tick() is
        untouched, so armed-no-fault plans stay bit-identical)."""
        if self.watchdog is None:
            return self._serve_plain(max_ticks)
        return self._serve_watched(max_ticks)

    def _serve_plain(self, max_ticks: int = 64) -> dict[int, np.ndarray]:
        """The disarmed serve loop (the historical code path).

        Each tick's micro-batches execute on one worker thread *per engine*
        (each engine runs its own dispatches in planned order): the CEFT
        makespan assumes the processor classes work in parallel, and the
        scoped-profile substrate makes concurrent engine traces safe.

        Failure semantics: a worker DEATH (:class:`WorkerLost` — a killed
        subprocess, a dead pipe) is degradation, not an abort.  The lost
        worker's pending dispatches re-enter the resident queues, the pool
        listener has already marked the class column fully degraded, and the
        next tick's nominal+degraded re-plan routes the in-flight workload
        to the survivors — their completed results are kept throughout.
        Each loss is recorded in ``self.failures`` with per-engine context.
        Engine ERRORS (an exception from a live engine) still fail the loop
        loudly, all concurrent failures aggregated — a silent partial result
        dict would pass smoke runs.  Losing the LAST live worker raises,
        aggregating every recorded loss."""
        done: dict[int, np.ndarray] = {}
        lock = threading.Lock()
        for _ in range(max_ticks):
            if not len(self.queue) and not self.resident:
                break
            if not self.pool.live_indices():
                agg = RuntimeError(
                    f"no live pool workers remain ({len(self.failures)} "
                    "lost): "
                    + "; ".join(f"{name}: {type(e).__name__}: {e}"
                                for name, e in self.failures))
                agg.failures = list(self.failures)
                raise agg
            errors: list[tuple[str, BaseException]] = []
            lost: list[tuple[str, WorkerLost, list[Dispatch]]] = []
            per_engine: dict[int, list[Dispatch]] = {}
            for d in self.tick():
                per_engine.setdefault(d.engine, []).append(d)

            def worker(name: str, ds: list[Dispatch]):
                for i, d in enumerate(ds):
                    try:
                        out = self.run_dispatch(d)
                    except WorkerLost as e:   # degradation: requeue the rest
                        with lock:
                            lost.append((name, e, ds[i:]))
                        return
                    except BaseException as e:  # surfaced after join, not lost
                        with lock:
                            errors.append((name, e))
                        return
                    with lock:
                        done.update(out)

            threads = [threading.Thread(target=worker,
                                        args=(self.slots[eng].name, ds))
                       for eng, ds in per_engine.items()]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            for name, e, pending in lost:
                self.failures.append((name, e))
                self._requeue(pending)
            if errors:
                # dead engines must fail the serve loop loudly -- silently
                # returning a partial result dict would pass smoke runs --
                # and ALL concurrent failures must surface: raising only the
                # first dropped every other engine's error on the floor
                if len(errors) == 1:
                    raise errors[0][1]
                agg = RuntimeError(
                    f"{len(errors)} engines failed concurrently: "
                    + "; ".join(f"{name}: {type(e).__name__}: {e}"
                                for name, e in errors))
                agg.failures = list(errors)   # originals, per-engine context
                raise agg from errors[0][1]
        return done

    def _serve_watched(self, max_ticks: int = 64) -> dict[int, np.ndarray]:
        """The armed serve loop: the same admit/plan/dispatch pipeline as
        the plain loop, with every dispatch armed on the deadline watchdog
        and completion made first-attempt-wins (rid dedup in _complete).

        Fault-containment differences from the plain loop:

        * every attempt carries ``deadline_factor x planned_span``; overdue
          attempts walk the _on_overdue ladder (report+hedge / requeue /
          mark_lost),
        * engine worker threads are joined with a CAPPED timeout — a thread
          stuck in an unreleasable hang is abandoned (daemon), its
          un-completed dispatches requeued and already counted toward the
          offender's strikes, instead of blocking serve forever,
        * budget-eligible lost workers are relaunched each tick through the
          pool's bounded exponential backoff.
        """
        wd = self.watchdog
        with self._serve_lock:
            self._serve_done = {}
            self._wd_requeue = []
        wd.start()
        max_budget = wd.min_deadline
        try:
            for _ in range(max_ticks):
                with self._serve_lock:
                    pending_wd, self._wd_requeue = self._wd_requeue, []
                    done_view = dict(self._serve_done)
                self._requeue(pending_wd, done=done_view)
                self.pool.maybe_relaunch_lost()
                if not len(self.queue) and not self.resident:
                    # queue drained: wait out in-flight attempts (hedges,
                    # abandoned originals) — their completions land in
                    # _serve_done, their strikes may still requeue work
                    t_end = time.monotonic() + 1.0 + 4.0 * max_budget
                    while wd.inflight() and time.monotonic() < t_end:
                        time.sleep(min(wd.poll_interval, 0.01))
                    with self._serve_lock:
                        pending_wd, self._wd_requeue = self._wd_requeue, []
                        done_view = dict(self._serve_done)
                    self._requeue(pending_wd, done=done_view)
                    if not len(self.queue) and not self.resident:
                        break
                    continue
                if not self.pool.live_indices():
                    agg = RuntimeError(
                        f"no live pool workers remain ({len(self.failures)} "
                        "lost): "
                        + "; ".join(f"{name}: {type(e).__name__}: {e}"
                                    for name, e in self.failures))
                    agg.failures = list(self.failures)
                    raise agg
                errors: list[tuple[str, BaseException]] = []
                lost: list[tuple[str, WorkerLost, list[Dispatch]]] = []
                lock = threading.Lock()
                per_engine: dict[int, list[Dispatch]] = {}
                for d in self.tick():
                    per_engine.setdefault(d.engine, []).append(d)
                for ds in per_engine.values():
                    for d in ds:
                        max_budget = max(max_budget,
                                         wd.budget(self.planned_span(d)))
                progress = {eng: 0 for eng in per_engine}

                def worker(eng: int, name: str, ds: list[Dispatch]):
                    for i, d in enumerate(ds):
                        seq = next_seq()
                        # armed from the propagated latest-finish when the
                        # batch carries an SLO, the flat budget otherwise
                        wd.arm(seq, d, planned_span=self.planned_span(d),
                               engine=eng,
                               on_critical_path=d.on_critical_path,
                               budget=self.dispatch_budget(d))
                        try:
                            out = self.run_dispatch(d)
                        except WorkerLost as e:
                            with lock:
                                lost.append((name, e, ds[i:]))
                                progress[eng] = len(ds)  # loss path requeues
                            return
                        except BaseException as e:
                            with lock:
                                errors.append((name, e))
                                progress[eng] = len(ds)
                            return
                        finally:
                            wd.disarm(seq)
                        self._complete(d, out)
                        with lock:
                            progress[eng] = i + 1

                threads = [(eng, threading.Thread(
                                target=worker,
                                args=(eng, self.slots[eng].name, ds),
                                daemon=True))
                           for eng, ds in per_engine.items()]
                for _, t in threads:
                    t.start()
                # capped join: long enough for every planned span plus the
                # full three-strike ladder, short enough that an
                # unreleasable hang cannot wedge the loop
                deadline = time.monotonic() + 1.0 + 4.0 * max_budget
                for eng, t in threads:
                    t.join(timeout=max(0.0, deadline - time.monotonic()))
                    if t.is_alive():
                        # abandon the stuck thread (daemon; a late result is
                        # deduped by rid) and take back its unfinished work
                        with lock:
                            done_at = progress[eng]
                        name = self.slots[eng].name
                        e = WorkerLost(name, eng, "hung past join deadline")
                        with lock:
                            lost.append((name, e, per_engine[eng][done_at:]))
                        try:
                            self.pool.mark_lost(eng, "hung past join deadline")
                        except Exception:
                            pass
                with self._serve_lock:
                    done_view = dict(self._serve_done)
                for name, e, pending in lost:
                    self.failures.append((name, e))
                    self._requeue(pending, done=done_view)
                if errors:
                    if len(errors) == 1:
                        raise errors[0][1]
                    agg = RuntimeError(
                        f"{len(errors)} engines failed concurrently: "
                        + "; ".join(f"{name}: {type(e).__name__}: {e}"
                                    for name, e in errors))
                    agg.failures = list(errors)
                    raise agg from errors[0][1]
        finally:
            wd.stop()
        with self._serve_lock:
            done, self._serve_done = self._serve_done, None
        return done
