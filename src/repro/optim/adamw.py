"""AdamW from scratch (no optax): decoupled weight decay, global-norm clip,
bias correction, configurable moment dtype (bf16 moments for llama3-405b)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, dt), p)
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def moment_specs(self, spec_tree):
        """PSpec tree for the moments (same logical axes as params)."""
        from ..models.common import PSpec, tree_map_pspec
        def f(_, p):
            return PSpec(p.shape, p.logical, init="zeros", dtype=self.moment_dtype)
        return tree_map_pspec(f, spec_tree)

    def update(self, grads, state: AdamWState, params):
        cnt = state.count + 1
        lr = self.lr(cnt) if callable(self.lr) else self.lr
        # global-norm clip in fp32
        leaves = jax.tree.leaves(grads)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
        bc1 = 1.0 - self.b1 ** cnt.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** cnt.astype(jnp.float32)
        dt = jnp.dtype(self.moment_dtype)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v2 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            step = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * step
            return p2.astype(p.dtype), m2.astype(dt), v2.astype(dt)

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, AdamWState(cnt, new_m, new_v), gn
