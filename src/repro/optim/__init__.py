"""repro.optim — optimizer + schedules built from scratch."""
from .adamw import AdamW, AdamWState
from .schedules import for_config, warmup_cosine, wsd

__all__ = ["AdamW", "AdamWState", "for_config", "warmup_cosine", "wsd"]
