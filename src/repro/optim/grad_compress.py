"""Int8 gradient compression with error feedback for the cross-pod (DCN)
reduction.

The pod axis carries pure data parallelism over the slow inter-pod fabric; the
gradient all-reduce there is the dominant DCN collective.  Compressing it 4x
(f32 -> int8 with per-tensor scale) cuts the §Roofline collective term on the
pod axis proportionally.  Error feedback keeps the *accumulated* quantization
error bounded: the residual e_t is added back before the next quantization, so
the scheme is unbiased over time (Karimireddy et al. 2019).

``ef_quantize`` is the pure building block (tested for the error-feedback
invariant); ``compressed_psum`` is the shard_map form that performs the actual
int8 wire transfer on a pod-axis mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..substrate import shard_map


def _quant(x: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def ef_quantize(g: jnp.ndarray, ef: jnp.ndarray):
    """Error-feedback int8 round trip: returns (g_hat, new_ef) with the
    invariant g + ef == g_hat + new_ef (up to float eps)."""
    corrected = g.astype(jnp.float32) + ef
    q, scale = _quant(corrected)
    g_hat = _dequant(q, scale)
    return g_hat, corrected - g_hat


def ef_quantize_tree(grads, ef_tree):
    out = jax.tree.map(ef_quantize, grads, ef_tree)
    g_hat = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g_hat, new_ef


def init_ef(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jnp.ndarray, mesh, axis: str = "pod"):
    """All-reduce x over ``axis`` transferring int8 on the wire.

    shard_map over the pod axis: each pod quantizes its partial, the int8
    payload crosses the DCN (all_gather), and each pod dequantizes + sums
    locally.  4x fewer DCN bytes than an f32 psum at <0.4% per-step error
    (error feedback at the caller keeps it unbiased over steps).
    """
    spec = P(*(axis if ax == axis else None for ax in mesh.axis_names))
    rep = P(*(None for _ in mesh.axis_names))

    def body(xs):
        q, scale = _quant(xs)
        qs = jax.lax.all_gather(q, axis)              # int8 on the wire
        ss = jax.lax.all_gather(scale, axis)
        return jnp.sum(qs.astype(jnp.float32) * ss.reshape(
            (-1,) + (1,) * xs.ndim), axis=0)

    fn = shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=rep)
    return fn(x)
