"""Learning-rate schedules: warmup+cosine, and WSD (warmup-stable-decay,
MiniCPM's schedule [arXiv:2404.06395] -- minicpm-2b trains with this)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak: float, warmup: int, total: int, floor_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        wu = peak * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, wu, cos)
    return lr


def wsd(peak: float, warmup: int, total: int, decay_frac: float = 0.1,
        floor_frac: float = 0.01):
    """Warmup -> Stable (constant peak) -> Decay (last decay_frac of steps,
    exponential-ish linear drop to floor)."""
    decay_start = int(total * (1.0 - decay_frac))

    def lr(step):
        s = step.astype(jnp.float32)
        wu = peak * s / max(warmup, 1)
        t = jnp.clip((s - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
        dec = peak * jnp.exp(jnp.log(floor_frac) * t)  # geometric decay to floor
        stable = jnp.full_like(s, peak)
        out = jnp.where(s < warmup, wu, jnp.where(s < decay_start, stable, dec))
        return out
    return lr


def for_config(schedule: str, peak: float, warmup: int, total: int):
    if schedule == "wsd":
        return wsd(peak, warmup, total)
    return warmup_cosine(peak, warmup, total)
