"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""
from __future__ import annotations

import jax.numpy as jnp

BIG = 3.0e38  # plain float: jnp scalars would be captured as consts by pallas_call


def minplus_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Tropical (min-plus) matrix product: C[i,j] = min_k A[i,k] + B[k,j].

    The algebraic core of shortest/longest-path relaxation; CEFT's inner
    ``min_{p_l} CEFT(t_k, p_l) + comm(p_l, p_j)`` is one row of this product.
    """
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def edge_relax_ref(pv, pdata, L, bw):
    """Edge-centric relaxation oracle (the CSR sweep's inner contraction).

    pv: (E, P) gathered parent CEFT values; pdata: (E,); L: (P,); bw: (P, P).
    Returns (minl (E, P), argl (E, P) int32).
    """
    P = L.shape[0]
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)
    comm = (L[:, None] + pdata[:, None, None] / bw) * off           # (E,Pl,Pj)
    cand = pv[:, :, None] + comm                                     # (E,Pl,Pj)
    return jnp.min(cand, axis=1), jnp.argmin(cand, axis=1).astype(jnp.int32)


def edge_relax_superstep_ref(pv, pdata, L, bw):
    """Stacked super-step relaxation oracle: ``edge_relax_ref`` over a fused
    run's (R, E) stacked edge tables (or a batch axis) in one shot.

    pv: (R, E, P); pdata: (R, E); L: (P,); bw: (P, P).
    Returns (minl (R, E, P), argl (R, E, P) int32).
    """
    P = L.shape[0]
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)
    comm = (L[:, None] + pdata[..., None, None] / bw) * off        # (R,E,Pl,Pj)
    cand = pv[..., :, None] + comm                                  # (R,E,Pl,Pj)
    return jnp.min(cand, axis=-2), jnp.argmin(cand, axis=-2).astype(jnp.int32)


def ceft_relax_ref(pv, pdata, validp, L, bw):
    """One CEFT level relaxation (paper eq. 4 inner loops), dense form.

    pv     : (W, D, P)  CEFT values of the D (padded) parents of W tasks
    pdata  : (W, D)     data volume on each parent edge
    validp : (W, D)     1.0 for real parents, 0.0 for padding
    L      : (P,)       per-class communication startup
    bw     : (P, P)     class-pair bandwidth

    Returns (maxk (W,P) float32, argk (W,P) int32, argl (W,P) int32):
    max over parents of (min over parent classes of value+comm), plus the
    argmax parent slot and that parent's argmin class (for path backtracking).
    Padded-parent rows yield -BIG; caller masks on ``validp.any(-1)``.
    """
    P = L.shape[0]
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)
    comm = (L[:, None] + pdata[..., None, None] / bw) * off        # (W,D,P,P)
    cand = pv[..., :, None] + comm                                  # (W,D,Pl,Pj)
    argl = jnp.argmin(cand, axis=2).astype(jnp.int32)               # (W,D,Pj)
    minl = jnp.min(cand, axis=2)                                    # (W,D,Pj)
    minl = jnp.where(validp[..., None] > 0, minl, -BIG)
    argk = jnp.argmax(minl, axis=1).astype(jnp.int32)               # (W,Pj)
    maxk = jnp.max(minl, axis=1)
    argl_sel = jnp.take_along_axis(argl, argk[:, None, :], axis=1)[:, 0, :]
    # tasks with no valid parent have undefined argk/argl: pin them to -1
    has = (validp > 0).any(axis=1)[:, None]
    argk = jnp.where(has, argk, -1)
    argl_sel = jnp.where(has, argl_sel, -1)
    return maxk, argk, argl_sel
