"""Fused CEFT level-relaxation Pallas kernel.

One kernel invocation relaxes a whole topological level (paper Algorithm 1
lines 6-18, batched over the level's tasks):

    maxk[w, j] = max_d  min_l  pv[w, d, l] + comm(l, j | pdata[w, d])

The XLA formulation materializes the (W, D, P, P) candidate tensor in HBM; the
kernel keeps everything in VMEM: the grid tiles W, and the kernel loops over
parent slots d, building only a (bw_, P, P) candidate tile per step and folding
it into a running (masked) max with argmax/argmin bookkeeping for the path
backtrack.  HBM traffic drops from O(W D P^2) to O(W D P) -- the relaxation is
turned from memory-bound into VPU-bound (see EXPERIMENTS.md §Perf).

TPU notes: P is the lane dimension -- pad classes to a multiple of 128 for
peak efficiency (ops.py handles padding); bw_ (tasks per tile) is the sublane
dimension, default 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38  # plain float: jnp scalars would be captured as consts by pallas_call


def _relax_kernel(pv_ref, pdata_ref, valid_ref, L_ref, bw_ref, max_ref, argk_ref, argl_ref):
    pv = pv_ref[...]          # (bw_, D, P)
    pdata = pdata_ref[...]    # (bw_, D)
    valid = valid_ref[...]    # (bw_, D)
    L = L_ref[...]            # (P,)
    bw = bw_ref[...]          # (P, P)
    W, D, P = pv.shape
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)

    def body(d, carry):
        run_max, run_argk, run_argl = carry
        pvd = jax.lax.dynamic_index_in_dim(pv, d, 1, keepdims=False)      # (W, P)
        dat = jax.lax.dynamic_index_in_dim(pdata, d, 1, keepdims=False)   # (W,)
        vd = jax.lax.dynamic_index_in_dim(valid, d, 1, keepdims=False)    # (W,)
        comm = (L[None, :, None] + dat[:, None, None] / bw[None]) * off   # (W, Pl, Pj)
        cand = pvd[:, :, None] + comm                                     # (W, Pl, Pj)
        minl = jnp.min(cand, axis=1)                                      # (W, Pj)
        argl = jnp.argmin(cand, axis=1).astype(jnp.int32)
        minl = jnp.where(vd[:, None] > 0, minl, -BIG)
        upd = minl > run_max  # strict: first maximal parent wins, like argmax
        return (
            jnp.where(upd, minl, run_max),
            jnp.where(upd, d, run_argk),
            jnp.where(upd, argl, run_argl),
        )

    init = (
        jnp.full((W, P), -BIG, pv.dtype),
        jnp.zeros((W, P), jnp.int32),
        jnp.zeros((W, P), jnp.int32),
    )
    run_max, run_argk, run_argl = jax.lax.fori_loop(0, D, body, init)
    max_ref[...] = run_max
    argk_ref[...] = run_argk
    argl_ref[...] = run_argl


def _edge_relax_kernel(pv_ref, pdata_ref, L_ref, bw_ref, min_ref, argl_ref):
    """Segment-tiled edge relaxation (ISSUE 3): one tile = block_e contiguous
    edges of a level's CSR segment run.  Builds only a (block_e, P, P)
    candidate tile in VMEM -- the O(e·P²) work of the CSR sweep with no
    (W, D) padding -- and reduces over the parent class in-register.  The
    per-child ``segment_max`` stays in XLA where the scatter is native."""
    pv = pv_ref[...]          # (block_e, P)
    pdata = pdata_ref[...]    # (block_e,)
    L = L_ref[...]            # (P,)
    bw = bw_ref[...]          # (P, P)
    P = pv.shape[1]
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)
    comm = (L[None, :, None] + pdata[:, None, None] / bw[None]) * off  # (E,Pl,Pj)
    cand = pv[:, :, None] + comm                                       # (E,Pl,Pj)
    min_ref[...] = jnp.min(cand, axis=1)
    argl_ref[...] = jnp.argmin(cand, axis=1).astype(jnp.int32)


def _edge_relax_superstep_kernel(pv_ref, pdata_ref, L_ref, bw_ref, min_ref, argl_ref):
    """Stacked super-step tile (ISSUE 4): one grid step relaxes one
    (level, edge-block) tile of a fused run's stacked (R, E, P) edge tables —
    the same VMEM-resident (block_e, P, P) candidate tile as
    ``_edge_relax_kernel``, with the run (or batch) axis as an outer grid
    dimension so a whole super-step's relaxation is one ``pallas_call``."""
    pv = pv_ref[...][0]       # (block_e, P)
    pdata = pdata_ref[...][0]  # (block_e,)
    L = L_ref[...]            # (P,)
    bw = bw_ref[...]          # (P, P)
    P = pv.shape[1]
    off = 1.0 - jnp.eye(P, dtype=pv.dtype)
    comm = (L[None, :, None] + pdata[:, None, None] / bw[None]) * off  # (E,Pl,Pj)
    cand = pv[:, :, None] + comm                                       # (E,Pl,Pj)
    min_ref[...] = jnp.min(cand, axis=1)[None]
    argl_ref[...] = jnp.argmin(cand, axis=1).astype(jnp.int32)[None]


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def edge_relax_superstep_pallas(
    pv: jnp.ndarray,      # (R, E, P) stacked gathered parent CEFT values, float32
    pdata: jnp.ndarray,   # (R, E)    data volume per edge, float32
    L: jnp.ndarray,       # (P,)      float32
    bw: jnp.ndarray,      # (P, P)    float32
    *,
    block_e: int = 128,
    interpret: bool = False,
):
    R, E, P = pv.shape
    assert E % block_e == 0, "pad via ops.edge_relax_superstep"
    grid = (R, E // block_e)
    return pl.pallas_call(
        _edge_relax_superstep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_e, P), lambda r, i: (r, i, 0)),
            pl.BlockSpec((1, block_e), lambda r, i: (r, i)),
            pl.BlockSpec((P,), lambda r, i: (0,)),
            pl.BlockSpec((P, P), lambda r, i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_e, P), lambda r, i: (r, i, 0)),
            pl.BlockSpec((1, block_e, P), lambda r, i: (r, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, E, P), pv.dtype),
            jax.ShapeDtypeStruct((R, E, P), jnp.int32),
        ],
        interpret=interpret,
    )(pv, pdata, L, bw)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def edge_relax_pallas(
    pv: jnp.ndarray,      # (E, P) gathered parent CEFT values, float32
    pdata: jnp.ndarray,   # (E,)   data volume per edge, float32
    L: jnp.ndarray,       # (P,)   float32
    bw: jnp.ndarray,      # (P, P) float32
    *,
    block_e: int = 128,
    interpret: bool = False,
):
    E, P = pv.shape
    assert E % block_e == 0, "pad via ops.edge_relax"
    grid = (E // block_e,)
    return pl.pallas_call(
        _edge_relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, P), lambda i: (i, 0)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((P,), lambda i: (0,)),
            pl.BlockSpec((P, P), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_e, P), lambda i: (i, 0)),
            pl.BlockSpec((block_e, P), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E, P), pv.dtype),
            jax.ShapeDtypeStruct((E, P), jnp.int32),
        ],
        interpret=interpret,
    )(pv, pdata, L, bw)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def ceft_relax_pallas(
    pv: jnp.ndarray,      # (W, D, P) float32
    pdata: jnp.ndarray,   # (W, D)    float32
    validp: jnp.ndarray,  # (W, D)    float32 mask (1 real parent / 0 padding)
    L: jnp.ndarray,       # (P,)      float32
    bw: jnp.ndarray,      # (P, P)    float32
    *,
    block_w: int = 8,
    interpret: bool = False,
):
    W, D, P = pv.shape
    assert W % block_w == 0, "pad via ops.ceft_relax"
    grid = (W // block_w,)
    return pl.pallas_call(
        _relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w, D, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_w, D), lambda i: (i, 0)),
            pl.BlockSpec((block_w, D), lambda i: (i, 0)),
            pl.BlockSpec((P,), lambda i: (0,)),
            pl.BlockSpec((P, P), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_w, P), lambda i: (i, 0)),
            pl.BlockSpec((block_w, P), lambda i: (i, 0)),
            pl.BlockSpec((block_w, P), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((W, P), pv.dtype),
            jax.ShapeDtypeStruct((W, P), jnp.int32),
            jax.ShapeDtypeStruct((W, P), jnp.int32),
        ],
        interpret=interpret,
    )(pv, pdata, validp, L, bw)
