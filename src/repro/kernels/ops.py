"""Jit'd public wrappers around the Pallas kernels: padding to block multiples,
backend selection (TPU kernel vs interpret-mode validation on CPU), and
adapters matching ``repro.core.ceft_jax``'s relax_fn signature."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .ceft_relax import (
    ceft_relax_pallas,
    edge_relax_pallas,
    edge_relax_superstep_pallas,
)
from .minplus import BIG, minplus_pallas
from . import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value) -> jnp.ndarray:
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


def minplus(a, b, *, bm: int = 256, bk: int = 16, bn: int = 256, interpret: bool | None = None):
    """Tropical matmul C[i,j] = min_k A[i,k]+B[k,j], padded to block multiples
    with +BIG (the (min,+) identity) and sliced back."""
    if interpret is None:
        interpret = not _on_tpu()
    m, n = a.shape[0], b.shape[1]
    a = _pad_to(_pad_to(a, 0, bm, BIG), 1, bk, BIG)
    b = _pad_to(_pad_to(b, 0, bk, BIG), 1, bn, BIG)
    out = minplus_pallas(a, b, bm=bm, bk=bk, bn=bn, interpret=interpret)
    return out[:m, :n]


def ceft_relax(pv, pdata, validp, L, bw, *, block_w: int = 8, interpret: bool | None = None):
    """Fused CEFT level relaxation (see ceft_relax.py).  Pads the task axis to
    a block multiple (padding rows carry validp=0) and, on TPU, the class axis
    to the 128-lane tile (padded classes get +BIG values so they are never
    selected)."""
    if interpret is None:
        interpret = not _on_tpu()
    W, D, P = pv.shape
    pv = _pad_to(pv, 0, block_w, 0.0)
    pdata = _pad_to(pdata, 0, block_w, 0.0)
    validp = _pad_to(validp, 0, block_w, 0.0)
    if _on_tpu():
        pv = _pad_to(pv, 2, 128, BIG)
        L = _pad_to(L, 0, 128, BIG)
        bw = _pad_to(_pad_to(bw, 0, 128, 1.0), 1, 128, 1.0)
    maxk, argk, argl = ceft_relax_pallas(
        pv, pdata, validp, L, bw, block_w=block_w, interpret=interpret
    )
    maxk, argk, argl = maxk[:W, :P], argk[:W, :P], argl[:W, :P]
    # tasks with no valid parent have undefined argk/argl: pin them to -1
    has = (validp[:W] > 0).any(axis=1)[:, None]
    return maxk, jnp.where(has, argk, -1), jnp.where(has, argl, -1)


def pallas_relax(pv, pdata, validp, L, bw):
    """Drop-in ``relax_fn`` for repro.core.ceft_jax._sweep: same contract as
    ``xla_relax`` (validp arrives as bool)."""
    maxk, argk, argl = ceft_relax(pv, pdata, validp.astype(pv.dtype), L, bw)
    return maxk, argk, argl


def edge_relax(pv, pdata, L, bw, *, block_e: int = 128, interpret: bool | None = None):
    """Segment-tiled fused edge relaxation (see ceft_relax.py).  Pads the edge
    axis to a block multiple (padded rows are sliced off; the CSR sweep masks
    them anyway) and, on TPU, the class axis to the 128-lane tile (padded
    classes get +BIG values so they are never selected)."""
    if interpret is None:
        interpret = not _on_tpu()
    E, P = pv.shape
    pv = _pad_to(pv, 0, block_e, 0.0)
    pdata = _pad_to(pdata, 0, block_e, 0.0)
    if _on_tpu():
        pv = _pad_to(pv, 1, 128, BIG)
        L = _pad_to(L, 0, 128, BIG)
        bw = _pad_to(_pad_to(bw, 0, 128, 1.0), 1, 128, 1.0)
    minl, argl = edge_relax_pallas(pv, pdata, L, bw, block_e=block_e, interpret=interpret)
    return minl[:E, :P], argl[:E, :P]


def edge_relax_superstep(pv, pdata, L, bw, *, block_e: int = 128,
                         interpret: bool | None = None):
    """Stacked super-step edge relaxation (see ceft_relax.py): the fused-run
    (R, E, P) form with the run/batch axis as an outer grid dimension.  Pads
    the edge axis to a block multiple (padded rows are sliced off; the CSR
    sweep masks them anyway) and, on TPU, the class axis to the 128-lane
    tile (padded classes get +BIG values so they are never selected)."""
    if interpret is None:
        interpret = not _on_tpu()
    R, E, P = pv.shape
    pv = _pad_to(pv, 1, block_e, 0.0)
    pdata = _pad_to(pdata, 1, block_e, 0.0)
    if _on_tpu():
        pv = _pad_to(pv, 2, 128, BIG)
        L = _pad_to(L, 0, 128, BIG)
        bw = _pad_to(_pad_to(bw, 0, 128, 1.0), 1, 128, 1.0)
    minl, argl = edge_relax_superstep_pallas(
        pv, pdata, L, bw, block_e=block_e, interpret=interpret
    )
    return minl[:, :E, :P], argl[:, :E, :P]


def pallas_edge_relax(pv, pdata, L, bw):
    """Drop-in ``relax_fn`` for repro.core.ceft_jax.ceft_jax_csr: same contract
    as ``xla_edge_relax``."""
    return edge_relax(pv, pdata, L, bw)
