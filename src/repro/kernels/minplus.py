"""Blocked tropical (min-plus) matmul Pallas kernel.

TPU adaptation of the paper's relaxation hot-spot (DESIGN.md §2): the classic
(i, j, k) matmul grid with BlockSpec VMEM tiling, accumulating with ``min``
instead of ``+`` and combining with ``+`` instead of ``*``.  The contraction
blocks are kept *shallow* (bk << bm, bn) because the (bm, bk, bn) candidate
tensor must live in VMEM: with (256, 16, 256) fp32 that is 4 MiB -- inside the
~16 MiB VMEM budget with double buffering, while bm/bn stay multiples of the
128-lane MXU/VPU tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38  # plain float: jnp scalars would be captured as consts by pallas_call


def _minplus_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, BIG)

    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)
    cand = jnp.min(a[:, :, None] + b[None, :, :], axis=1)
    o_ref[...] = jnp.minimum(o_ref[...], cand)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def minplus_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 256,
    bk: int = 16,
    bn: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """C[i,j] = min_k A[i,k] + B[k,j] with (bm, bk, bn) VMEM tiles.

    Shapes must be multiples of the block sizes (ops.py pads with +BIG, which
    is the identity of the (min, +) semiring).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, "pad via ops.minplus"
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)
