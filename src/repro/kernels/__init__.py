"""repro.kernels — Pallas TPU kernels for the paper's relaxation hot-spot.

minplus    : blocked tropical (min-plus) matmul
ceft_relax : fused CEFT level relaxation (min over parent classes -> masked max
             over parents) with argmin/argmax path bookkeeping
edge_relax : segment-tiled edge-centric relaxation for the CSR CEFT sweep
             (per-edge min over parent classes; O(e·P²) work, VMEM-resident)
edge_relax_superstep : the stacked super-step tile variant — a fused run's
             (R, E, P) edge tables relaxed in one pallas_call (run/batch axis
             as an outer grid dimension).  Validated standalone against its
             oracle; the sequential CSR sweep relaxes level-by-level inside
             lax.scan, so this is the building block for the whole-run TPU
             kernel path (ROADMAP), not yet wired into the sweep
ref        : pure-jnp oracles; every kernel is validated against these in
             interpret mode across shape/dtype sweeps (tests/test_kernels.py)
"""
from .ops import (
    ceft_relax,
    edge_relax,
    edge_relax_superstep,
    minplus,
    pallas_edge_relax,
    pallas_relax,
)
from . import ref

__all__ = [
    "ceft_relax", "edge_relax", "edge_relax_superstep", "minplus",
    "pallas_edge_relax", "pallas_relax", "ref",
]
