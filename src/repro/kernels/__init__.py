"""repro.kernels — Pallas TPU kernels for the paper's relaxation hot-spot.

minplus    : blocked tropical (min-plus) matmul
ceft_relax : fused CEFT level relaxation (min over parent classes -> masked max
             over parents) with argmin/argmax path bookkeeping
ref        : pure-jnp oracles; every kernel is validated against these in
             interpret mode across shape/dtype sweeps (tests/test_kernels.py)
"""
from .ops import ceft_relax, minplus, pallas_relax
from . import ref

__all__ = ["ceft_relax", "minplus", "pallas_relax", "ref"]
