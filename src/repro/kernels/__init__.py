"""repro.kernels — Pallas TPU kernels for the paper's relaxation hot-spot.

minplus    : blocked tropical (min-plus) matmul
ceft_relax : fused CEFT level relaxation (min over parent classes -> masked max
             over parents) with argmin/argmax path bookkeeping
edge_relax : segment-tiled edge-centric relaxation for the CSR CEFT sweep
             (per-edge min over parent classes; O(e·P²) work, VMEM-resident)
ref        : pure-jnp oracles; every kernel is validated against these in
             interpret mode across shape/dtype sweeps (tests/test_kernels.py)
"""
from .ops import ceft_relax, edge_relax, minplus, pallas_edge_relax, pallas_relax
from . import ref

__all__ = [
    "ceft_relax", "edge_relax", "minplus", "pallas_edge_relax",
    "pallas_relax", "ref",
]
