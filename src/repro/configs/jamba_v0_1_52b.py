"""Jamba v0.1 52B — Mamba+attention 1:7 interleave with MoE [arXiv:2403.19887].

32 layers, one attention layer per 8 (index 4), MoE (16 experts, top-2) on every
second layer; no positional encoding (use_rope=False).  Sub-quadratic sequence
mixing (28/32 layers are SSM) -> runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=65536, head_dim=128,
    n_experts=16, top_k=2, moe_every=2,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    attn_every=8, attn_pos=4,
    use_rope=False,
    supports_long=True,
)

SMOKE = ArchConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16,
    n_experts=4, top_k=2, moe_every=2,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_conv=4, ssm_chunk=16,
    attn_every=4, attn_pos=2,
    use_rope=False, loss_chunk=32,
    supports_long=True,
)
