"""Llama-3 405B — dense GQA, 128k vocab [arXiv:2407.21783].

optstate_dtype=bfloat16: fp32 AdamW moments put 405B at 19 GiB/chip on a
256-chip pod (> v5e 16 GiB HBM); bf16 moments bring params+opt to ~12.7 GiB
(documented trade-off, DESIGN.md §6 / EXPERIMENTS.md §Dry-run).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
    vocab=128256, head_dim=128, rope_theta=5e5,
    optstate_dtype="bfloat16",
)

SMOKE = ArchConfig(
    name="llama3-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=192,
    vocab=512, head_dim=8, rope_theta=5e5, optstate_dtype="bfloat16",
    loss_chunk=32,
)
