"""repro.configs — one module per assigned architecture (+ smoke variants)."""
from . import (
    dbrx_132b,
    glm4_9b,
    granite_3_8b,
    jamba_v0_1_52b,
    llama3_405b,
    mamba2_2_7b,
    minicpm_2b,
    mixtral_8x22b,
    qwen2_vl_72b,
    whisper_tiny,
)
from .base import ArchConfig, SHAPES, ShapeCell, cells_for, smoke_cell

_MODULES = {
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "granite-3-8b": granite_3_8b,
    "llama3-405b": llama3_405b,
    "minicpm-2b": minicpm_2b,
    "glm4-9b": glm4_9b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "whisper-tiny": whisper_tiny,
    "mixtral-8x22b": mixtral_8x22b,
    "dbrx-132b": dbrx_132b,
    "mamba2-2.7b": mamba2_2_7b,
}

ARCHS = list(_MODULES)


def get(name: str, smoke: bool = False) -> ArchConfig:
    mod = _MODULES[name]
    return mod.SMOKE if smoke else mod.CONFIG


__all__ = ["ARCHS", "ArchConfig", "SHAPES", "ShapeCell", "cells_for", "get",
           "smoke_cell"]
