"""Whisper tiny — encoder-decoder, conv audio frontend stubbed [arXiv:2212.04356].

input_specs() supplies precomputed frame embeddings (1500, d) in place of the
conv frontend.  GELU 2-proj MLPs, MHA (kv == q heads).  Enc-dec => decode cells
run (decoder self-attn KV cache sized to the cell's seq_len; cross-attn over
the fixed 1500-frame encoder output).  Full attention -> long_500k skipped.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab=51865, head_dim=64, enc_seq=1500,
    mlp_style="gelu", use_rope=False,
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, head_dim=16, enc_seq=64,
    mlp_style="gelu", use_rope=False, loss_chunk=32,
)
