"""Mamba-2 2.7B — attention-free SSD (state-space duality) [arXiv:2405.21060].

64 pure-SSM layers, d_state=128, O(1) decode state -> runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=64, ssm_conv=4,
    tie_embeddings=True,
    supports_long=True,
)

SMOKE = ArchConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=256,
    ssm_state=16, ssm_expand=2, ssm_head_dim=32, ssm_chunk=16, ssm_conv=4,
    tie_embeddings=True, loss_chunk=32,
    supports_long=True,
)
