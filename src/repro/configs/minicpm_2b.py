"""MiniCPM 2B — llama-like MHA, tied embeddings, WSD schedule [arXiv:2404.06395]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122753, head_dim=64,
    tie_embeddings=True, schedule="wsd",
)

SMOKE = ArchConfig(
    name="minicpm-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=6, d_ff=96,
    vocab=256, head_dim=8, tie_embeddings=True, schedule="wsd", loss_chunk=32,
)
