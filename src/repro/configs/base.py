"""Architecture config schema + the assigned input-shape cells.

Every assigned architecture gets one module defining ``CONFIG`` (the exact
published dims) and ``SMOKE`` (a reduced same-family variant for CPU smoke
tests).  ``repro.configs.get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int             # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int                # per-expert FF width for MoE families
    vocab: int
    head_dim: int = 0        # 0 -> d_model // n_heads
    # -- MoE --
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1       # MoE replaces MLP every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    # -- SSM (Mamba-2 / SSD) --
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv: int = 4
    # -- hybrid --
    attn_every: int = 0      # jamba: 1 attention layer per 8 (index attn_pos)
    attn_pos: int = 4
    # -- attention flavour --
    window: int = 0          # sliding-window size (0 = full causal)
    use_rope: bool = True    # jamba: no positional encoding
    rope_theta: float = 1e4
    mrope: bool = False      # qwen2-vl multimodal RoPE
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    mlp_style: str = "swiglu"  # swiglu | gelu (whisper)
    schedule: str = "cosine"   # cosine | wsd (minicpm)
    # -- encoder-decoder --
    enc_layers: int = 0
    enc_seq: int = 1500      # whisper audio frames (stubbed frontend)
    # -- misc --
    frontend: str = "none"   # none | audio_stub | vision_stub
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    optstate_dtype: str = "float32"   # bf16 for llama3-405b (fits 16 GiB HBM)
    remat: str = "full"      # full | none  (activation checkpointing policy)
    loss_chunk: int = 512    # sequence chunking for the CE loss
    # -- shape-cell applicability --
    supports_long: bool = False   # run long_500k (sub-quadratic mixers only)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def period(self) -> int:
        """Layer-pattern period for the scanned stack."""
        if self.family == "hybrid":
            return self.attn_every
        return 1

    def layer_pattern(self) -> list[tuple[str, str]]:
        """(sequence-mixer, channel-mixer) per period position."""
        if self.family in ("dense", "vlm", "encdec"):  # encdec: decoder stack
            return [("attn", "mlp")]
        if self.family == "moe":
            return [("attn", "moe")]
        if self.family == "ssm":
            return [("ssm", "none")]
        if self.family == "hybrid":
            out = []
            for i in range(self.attn_every):
                mixer = "attn" if i == self.attn_pos else "ssm"
                channel = "moe" if (i % self.moe_every == 1) else "mlp"
                out.append((mixer, channel))
            return out
        raise ValueError(self.family)

    def n_params(self) -> int:
        """Analytic parameter count (excludes negligible norms/biases)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        for mixer, channel in self.layer_pattern():
            reps = self.n_layers // self.period
            if mixer == "attn":
                attn = d * self.hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * self.hd * d
                total += attn * reps
            else:
                di, st = self.d_inner, self.ssm_state
                ssm = d * (2 * di + 2 * st + self.ssm_heads) + di * d  # in/out proj (+BC, dt)
                total += ssm * reps
            mult = 3 if self.mlp_style == "swiglu" else 2
            if channel == "mlp":
                total += mult * d * ff * reps
            elif channel == "moe":
                total += (mult * d * ff * self.n_experts + d * self.n_experts) * reps
        if self.family == "encdec":
            # add encoder stack (self-attn + mlp) and decoder cross-attn
            mult = 3 if self.mlp_style == "swiglu" else 2
            attn = 4 * d * self.n_heads * self.hd
            total += self.enc_layers * (attn + mult * d * ff)
            total += self.n_layers * attn  # cross attention
        return total

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        mult = 3 if self.mlp_style == "swiglu" else 2
        reps = self.n_layers // self.period
        moe_positions = sum(1 for _, c in self.layer_pattern() if c == "moe")
        dense_moe = mult * d * ff * self.n_experts * moe_positions * reps
        active_moe = mult * d * ff * self.top_k * moe_positions * reps
        return self.n_params() - dense_moe + active_moe


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cells_for(cfg: ArchConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long:
        out.append("long_500k")
    return out


# smoke (seq_len, global_batch) per cell: shrunk to CPU scale but keeping the
# cell's character (shared by the dry-run and roofline --smoke paths)
_SMOKE_SCALE: dict[str, tuple[int, int]] = {
    "train_4k": (64, 8),
    "prefill_32k": (128, 4),
    "decode_32k": (128, 8),
    "long_500k": (512, 2),
}


def smoke_cell(cell_name: str) -> ShapeCell:
    """The named cell shrunk to smoke scale (fake-fleet / CPU testing)."""
    seq, batch = _SMOKE_SCALE[cell_name]
    return dataclasses.replace(SHAPES[cell_name], seq_len=seq, global_batch=batch)
