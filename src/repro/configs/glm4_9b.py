"""GLM-4 9B — dense, extreme GQA (2 kv heads), RoPE [hf:THUDM/glm-4-9b]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=151552, head_dim=128,
)

SMOKE = ArchConfig(
    name="glm4-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab=256, head_dim=16, loss_chunk=32,
)
