"""DBRX 132B — fine-grained MoE: 16 experts top-4 [hf:databricks/dbrx-base]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100352, head_dim=128,
    n_experts=16, top_k=4,
)

SMOKE = ArchConfig(
    name="dbrx-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=256, head_dim=16,
    n_experts=8, top_k=4, loss_chunk=32,
)
