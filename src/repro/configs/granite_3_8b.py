"""Granite-3 8B — dense GQA decoder [hf:ibm-granite]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12800,
    vocab=49155, head_dim=128,
)

SMOKE = ArchConfig(
    name="granite-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, loss_chunk=32,
)
