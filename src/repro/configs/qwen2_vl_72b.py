"""Qwen2-VL 72B — VLM backbone with M-RoPE [arXiv:2409.12191].

Vision frontend is a stub per the assignment: input_specs() feeds precomputed
patch embeddings plus (temporal, h, w) position ids; the backbone applies
multimodal RoPE over head_dim sections (16, 24, 24) * 2.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, head_dim=128,
    mrope=True, mrope_sections=(16, 24, 24), frontend="vision_stub",
)

SMOKE = ArchConfig(
    name="qwen2vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16,
    mrope=True, mrope_sections=(2, 3, 3), frontend="vision_stub", loss_chunk=32,
)
