"""Deterministic, restart-safe synthetic data pipeline.

Batch ``i`` is a pure function of (seed, i): after a crash/restart or an
elastic re-shard, resuming at step ``i`` reproduces the exact token stream --
no iterator state to checkpoint.  Tokens follow a skewed (zipf-ish) marginal
with a short-range bigram structure, so losses decrease measurably during the
smoke-scale training runs (a uniform stream would pin loss at ln(V)).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed random bigram table: next-token dist depends on prev bucket
        self.n_buckets = 16
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        base = 1.0 / ranks  # zipf marginal
        self.tables = np.stack([
            np.roll(base, rng.integers(0, cfg.vocab)) for _ in range(self.n_buckets)
        ])
        self.tables /= self.tables.sum(axis=1, keepdims=True)
        self.cum = np.cumsum(self.tables, axis=1)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        B, S = cfg.global_batch, cfg.seq_len
        u = rng.random((B, S + 1))
        toks = np.zeros((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab, B)
        for t in range(1, S + 1):
            bucket = toks[:, t - 1] % self.n_buckets
            toks[:, t] = np.argmax(self.cum[bucket] > u[:, t, None], axis=1)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def sharded_batch(self, step: int, shardings: dict):
        """Host batch -> committed device arrays under the given shardings."""
        host = self.batch(step)
        return {
            k: jax.device_put(v, shardings[k]) if k in shardings else v
            for k, v in host.items()
        }
